//! PJRT runtime: loads AOT artifacts (HLO text) and runs them on the hot
//! path with device-resident parameters.
//!
//! Wiring (see /opt/xla-example/load_hlo and DESIGN.md §3):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b`. HLO *text* is the interchange format —
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit ids).
//!
//! PJRT handles are `Rc`-based (not `Send`): the whole runtime lives on
//! one engine thread; the async front-end talks to it over channels
//! (`coordinator::engine`).

pub mod host;
pub mod weights;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::manifest::{Manifest, TensorSpec, VariantEntry};
pub use host::HostTensor;

/// Owns the PJRT client, the manifest, and a compile-once executable
/// cache keyed by variant name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<LoadedVariant>>>,
}

/// One AOT-compiled model variant: executable + device-resident params.
pub struct LoadedVariant {
    pub name: String,
    pub entry: VariantEntry,
    exe: xla::PjRtLoadedExecutable,
    param_bufs: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let (manifest, dir) = Manifest::load(artifacts_dir)?;
        Ok(Self { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &std::path::Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) a compiled variant with its weights
    /// uploaded once as device buffers.
    pub fn load(&self, name: &str) -> Result<Rc<LoadedVariant>> {
        if let Some(v) = self.cache.borrow().get(name) {
            return Ok(v.clone());
        }
        let entry = self.manifest.variant(name)?.clone();
        let hlo_path = self.dir.join(&entry.hlo);
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        let w = weights::load_weights(&self.dir.join(&entry.weights), &entry.params)?;
        let mut param_bufs = Vec::with_capacity(w.len());
        for t in &w {
            param_bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("uploading params for {name}: {e}"))?,
            );
        }
        let v = Rc::new(LoadedVariant {
            name: name.to_string(),
            entry,
            exe,
            param_bufs,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(name.to_string(), v.clone());
        Ok(v)
    }
}

/// Outputs of one executable invocation, decomposed from the root tuple.
pub struct ExecOutputs {
    pub tensors: Vec<HostTensor>,
}

impl LoadedVariant {
    pub fn config(&self) -> &crate::manifest::ModelConfig {
        &self.entry.config
    }

    /// Upload an f32 host tensor (no ownership transfer, no clone).
    pub fn upload_f32_ref(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.upload_f32(t)
    }

    /// Upload an i32 scalar (pos inputs).
    pub fn upload_pos(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.upload_i32_scalar(v)
    }

    fn upload_f32(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading input: {e}"))
    }

    fn upload_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(&[v], &[], None)
            .map_err(|e| anyhow::anyhow!("uploading scalar: {e}"))
    }

    /// Execute with data inputs as host tensors (`pos` inputs as i32
    /// scalars), params from the device-resident cache. Returns every
    /// output as a host tensor (the root tuple is decomposed).
    pub fn execute(&self, data: &[DataInput]) -> Result<ExecOutputs> {
        if data.len() != self.entry.inputs.len() {
            bail!(
                "{}: got {} data inputs, manifest wants {}",
                self.name,
                data.len(),
                self.entry.inputs.len()
            );
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(data.len());
        for (d, spec) in data.iter().zip(&self.entry.inputs) {
            bufs.push(self.upload_input(d, spec)?);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute_raw(&refs)
    }

    fn upload_input(&self, d: &DataInput, spec: &TensorSpec) -> Result<xla::PjRtBuffer> {
        match d {
            DataInput::F32(t) => {
                if t.shape != spec.shape {
                    bail!(
                        "{}: input {} shape {:?} != manifest {:?}",
                        self.name,
                        spec.name,
                        t.shape,
                        spec.shape
                    );
                }
                self.upload_f32(t)
            }
            DataInput::I32Scalar(v) => {
                if spec.dtype != "i32" {
                    bail!("{}: input {} is not i32", self.name, spec.name);
                }
                self.upload_i32_scalar(*v)
            }
        }
    }

    /// Execute with pre-uploaded input buffers (hot path; params appended
    /// from the device-resident cache). Returns the decomposed output
    /// literals WITHOUT host-vector conversion — callers copy only what
    /// they need (state feedback re-uploads literals directly; §Perf).
    pub fn execute_raw_literals(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(inputs.len() + self.param_bufs.len());
        args.extend(inputs.iter().copied());
        args.extend(self.param_bufs.iter());
        let res = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.name))?;
        let lit = res[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result of {}: {e}", self.name))?;
        if parts.len() != self.entry.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest wants {}",
                self.name,
                parts.len(),
                self.entry.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Re-upload a result literal as a device buffer (state feedback)
    /// through a caller-provided scratch slice (reused across ticks —
    /// no allocation on the hot path). `shape` is the manifest shape of
    /// the corresponding input.
    pub fn buffer_from_literal_via(
        &self,
        lit: &xla::Literal,
        scratch: &mut [f32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        lit.copy_raw_to::<f32>(scratch)
            .map_err(|e| anyhow::anyhow!("copying state literal: {e}"))?;
        self.client
            .buffer_from_host_buffer::<f32>(scratch, shape, None)
            .map_err(|e| anyhow::anyhow!("re-uploading state: {e}"))
    }

    /// Convert one output literal to a host tensor by output index.
    pub fn literal_to_host(&self, idx: usize, lit: &xla::Literal) -> Result<HostTensor> {
        let spec = &self.entry.outputs[idx];
        let v = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading output {}: {e}", spec.name))?;
        HostTensor::new(spec.shape.clone(), v)
    }

    /// Execute with pre-uploaded input buffers; all outputs converted to
    /// host tensors (cold paths / window runners).
    pub fn execute_raw(&self, inputs: &[&xla::PjRtBuffer]) -> Result<ExecOutputs> {
        let parts = self.execute_raw_literals(inputs)?;
        let mut tensors = Vec::with_capacity(parts.len());
        for (i, p) in parts.iter().enumerate() {
            tensors.push(self.literal_to_host(i, p)?);
        }
        Ok(ExecOutputs { tensors })
    }

    /// Upload one data input by manifest index (used by steppers to
    /// prepare token buffers without re-uploading state).
    pub fn upload_for(&self, idx: usize, d: &DataInput) -> Result<xla::PjRtBuffer> {
        self.upload_input(d, &self.entry.inputs[idx])
    }
}

/// A data input on its way to the device.
pub enum DataInput {
    F32(HostTensor),
    I32Scalar(i32),
}

/// Drives a continual-step variant over a stream: owns the state
/// feedback loop (new memories → next tick's inputs) with state kept as
/// device buffers between ticks.
pub struct Stepper {
    variant: Rc<LoadedVariant>,
    /// Device-resident state, indexed like `entry.inputs`.
    state: HashMap<usize, xla::PjRtBuffer>,
    pub pos: i32,
    wiring: Vec<(usize, usize)>,
    /// reusable host staging for state feedback (one per state tensor)
    scratch: Vec<Vec<f32>>,
}

/// Host-visible per-tick results (state stays on device).
pub struct TickOut {
    pub logits: HostTensor,
    pub out: HostTensor,
}

impl Stepper {
    pub fn new(variant: Rc<LoadedVariant>) -> Result<Self> {
        if !variant.entry.is_step() {
            bail!("{} is not a step variant", variant.name);
        }
        let wiring = variant.entry.state_wiring();
        let mut state = HashMap::new();
        let mut scratch = Vec::with_capacity(wiring.len());
        for &(_, inp) in &wiring {
            let spec = &variant.entry.inputs[inp];
            let z = HostTensor::zeros(spec.shape.clone());
            state.insert(inp, variant.upload_f32(&z)?);
            scratch.push(vec![0.0f32; spec.elems()]);
        }
        Ok(Self { variant, state, pos: 0, wiring, scratch })
    }

    pub fn variant(&self) -> &Rc<LoadedVariant> {
        &self.variant
    }

    /// Reset to a cold stream (zero memories, position 0).
    pub fn reset(&mut self) -> Result<()> {
        for (&inp, buf) in self.state.iter_mut() {
            let spec = &self.variant.entry.inputs[inp];
            let z = HostTensor::zeros(spec.shape.clone());
            *buf = self.variant.upload_f32(&z)?;
        }
        self.pos = 0;
        Ok(())
    }

    /// One continual tick: feed `tokens` (shape = manifest input 0),
    /// advance state, return logits + attended tokens.
    ///
    /// Hot path (§Perf): state outputs stay as literals and are
    /// re-uploaded directly — only logits and attended tokens cross into
    /// host vectors.
    pub fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        let variant = self.variant.clone(); // Rc bump, not a deep clone
        let entry = &variant.entry;
        let m = entry.config.m_tokens.max(1);
        // upload the non-state inputs for this tick
        let mut uploads: HashMap<usize, xla::PjRtBuffer> = HashMap::new();
        for (idx, spec) in entry.inputs.iter().enumerate() {
            if self.state.contains_key(&idx) {
                continue;
            }
            let buf = match spec.dtype.as_str() {
                "i32" => variant.upload_i32_scalar(self.pos)?,
                _ => {
                    anyhow::ensure!(
                        tokens.shape == spec.shape,
                        "{}: tick tokens shape {:?} != manifest {:?}",
                        variant.name,
                        tokens.shape,
                        spec.shape
                    );
                    variant.upload_f32(tokens)?
                }
            };
            uploads.insert(idx, buf);
        }
        let inputs: Vec<&xla::PjRtBuffer> = (0..entry.inputs.len())
            .map(|i| self.state.get(&i).or_else(|| uploads.get(&i)).unwrap())
            .collect();
        let parts = variant.execute_raw_literals(&inputs)?;
        drop(inputs);
        // feedback: state literal -> reused scratch -> device buffer
        for (si, &(out_idx, in_idx)) in self.wiring.iter().enumerate() {
            let shape = &entry.inputs[in_idx].shape;
            let buf = variant.buffer_from_literal_via(
                &parts[out_idx],
                &mut self.scratch[si],
                shape,
            )?;
            self.state.insert(in_idx, buf);
        }
        self.pos += m as i32;
        let logits = variant.literal_to_host(0, &parts[0])?;
        let out = variant.literal_to_host(1, &parts[1])?;
        Ok(TickOut { logits, out })
    }
}

/// Drives a window (non-continual) variant: keeps the token ring buffer
/// host-side and re-executes the full window each tick — the redundant
/// serving pattern the paper eliminates.
pub struct WindowRunner {
    variant: Rc<LoadedVariant>,
    ring: Vec<f32>,
    filled: usize,
    pub pos: i32,
}

impl WindowRunner {
    pub fn new(variant: Rc<LoadedVariant>) -> Result<Self> {
        if variant.entry.is_step() {
            bail!("{} is a step variant, not a window variant", variant.name);
        }
        let cfg = &variant.entry.config;
        let len = cfg.batch * cfg.window * cfg.d_in;
        Ok(Self { variant, ring: vec![0.0; len], filled: 0, pos: 0 })
    }

    pub fn variant(&self) -> &Rc<LoadedVariant> {
        &self.variant
    }

    pub fn reset(&mut self) {
        self.ring.iter_mut().for_each(|v| *v = 0.0);
        self.filled = 0;
        self.pos = 0;
    }

    /// Shift a token into the ring without executing (probe warmup for
    /// state-free models: only the final windows matter for clip
    /// features, so early ticks can skip the O(n²·d) recompute).
    pub fn push_only(&mut self, tokens: &HostTensor) -> Result<()> {
        let cfg = self.variant.entry.config.clone();
        let (b, n, d) = (cfg.batch, cfg.window, cfg.d_in);
        anyhow::ensure!(tokens.data.len() == b * d, "push_only wants (B, d) tokens");
        for lane in 0..b {
            let base = lane * n * d;
            self.ring.copy_within(base + d..base + n * d, base);
            let newest = base + (n - 1) * d;
            self.ring[newest..newest + d]
                .copy_from_slice(&tokens.data[lane * d..(lane + 1) * d]);
        }
        self.filled = (self.filled + 1).min(n);
        self.pos += 1;
        Ok(())
    }

    /// Push one token per batch lane (`tokens`: (B, d_in) flattened) and
    /// re-run the window. Shifting is O(n·d) host-side — negligible next
    /// to the O(n²·d) recompute this baseline performs.
    pub fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        let cfg = self.variant.entry.config.clone();
        let (b, n, d) = (cfg.batch, cfg.window, cfg.d_in);
        self.push_only(tokens)?;
        self.pos -= 1; // push_only advanced it; tick owns the increment
        let win = HostTensor::new(vec![b, n, d], self.ring.clone())?;
        let first_pos = self.pos - (n as i32 - 1);
        // build inputs per manifest spec — some baselines are posless
        let mut data = Vec::with_capacity(self.variant.entry.inputs.len());
        for spec in &self.variant.entry.inputs {
            data.push(match spec.dtype.as_str() {
                "i32" => DataInput::I32Scalar(first_pos),
                _ => DataInput::F32(win.clone()),
            });
        }
        let outs = self.variant.execute(&data)?;
        self.pos += 1;
        let mut tensors = outs.tensors;
        let out = tensors.swap_remove(1);
        let logits = tensors.swap_remove(0);
        Ok(TickOut { logits, out })
    }
}
