//! Host-side tensors: the boundary type between the coordinator and PJRT.

use anyhow::{bail, Result};

/// A dense f32 tensor on the host (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {shape:?} needs {want} elems, got {}", data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Borrow the contiguous slice for row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let cols = *self.shape.last().expect("rank >= 1");
        &self.data[i * cols..(i + 1) * cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = HostTensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn row_slices() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }
}
