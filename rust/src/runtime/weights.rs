//! Weight loading: `artifacts/weights/<key>.bin` is a concatenation of
//! f32 little-endian arrays in `param_spec` order (the manifest's
//! `params` field is the contract).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::ParamSpec;
use crate::runtime::host::HostTensor;

/// Read a weights blob and split it per the param spec.
pub fn load_weights(path: &Path, spec: &[ParamSpec]) -> Result<Vec<HostTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let total: usize = spec.iter().map(|p| p.elems()).sum();
    if bytes.len() != total * 4 {
        bail!(
            "weights {} has {} bytes, spec wants {} f32 ({} bytes)",
            path.display(),
            bytes.len(),
            total,
            total * 4
        );
    }
    let mut out = Vec::with_capacity(spec.len());
    let mut off = 0usize;
    for p in spec {
        let n = p.elems();
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
            data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        off += n;
        out.push(HostTensor::new(p.shape.clone(), data)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(vals: &[f32]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "deepcot_wtest_{}_{}.bin",
            std::process::id(),
            vals.len()
        ));
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn splits_in_order() {
        let p = write_tmp(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let spec = vec![
            ParamSpec { name: "a".into(), shape: vec![2, 2] },
            ParamSpec { name: "b".into(), shape: vec![2] },
        ];
        let w = load_weights(&p, &spec).unwrap();
        assert_eq!(w[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w[1].data, vec![5.0, 6.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn size_mismatch_errors() {
        let p = write_tmp(&[1.0, 2.0, 3.0]);
        let spec = vec![ParamSpec { name: "a".into(), shape: vec![2, 2] }];
        assert!(load_weights(&p, &spec).is_err());
        std::fs::remove_file(p).ok();
    }
}
