//! Every model the paper compares, behind one [`StreamModel`] trait:
//! per tick the serving layer feeds the newest token(s) and gets logits
//! + attended outputs, regardless of whether the implementation is
//! continual (Stepper), window-recompute (WindowRunner), a chained
//! MAT-SED pipeline, or a scalar CPU engine ([`ScalarModel`] /
//! [`BatchedScalarModel`] on ring-buffer memories, plus the frozen
//! pre-refactor [`NaiveScalarModel`] benchmark baseline).

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::manifest::ModelConfig;
use crate::nn::batched::BatchedScalarDeepCoT;
use crate::nn::encoder::ScalarDeepCoT;
use crate::nn::naive::NaiveScalarDeepCoT;
use crate::nn::params::ModelParams;
use crate::nn::tensor::Mat;
use crate::runtime::{HostTensor, LoadedVariant, Runtime, Stepper, TickOut, WindowRunner};

/// A model being served over a stream.
pub trait StreamModel {
    fn name(&self) -> &str;
    fn family(&self) -> &str;
    fn config(&self) -> &ModelConfig;
    /// Feed the newest m tokens: `tokens` is (B, m, d_in) flattened.
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut>;
    /// Advance the stream WITHOUT needing outputs. Continual models must
    /// still execute (their state advances through the executable);
    /// window models only shift their ring — the probe pipelines use
    /// this to skip redundant O(n²·d) recomputes during warmup.
    fn warm(&mut self, tokens: &HostTensor) -> Result<()> {
        self.tick(tokens).map(|_| ())
    }
    fn reset(&mut self) -> Result<()>;
}

/// Continual PJRT model (deepcot / cotransformer / xl step variants).
pub struct ContinualModel {
    name: String,
    stepper: Stepper,
}

impl ContinualModel {
    pub fn load(rt: &Runtime, variant: &str) -> Result<Self> {
        let v = rt.load(variant)?;
        Ok(Self { name: variant.to_string(), stepper: Stepper::new(v)? })
    }
}

impl StreamModel for ContinualModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        &self.stepper.variant().entry.family
    }
    fn config(&self) -> &ModelConfig {
        &self.stepper.variant().entry.config
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        self.stepper.tick(tokens)
    }
    fn reset(&mut self) -> Result<()> {
        self.stepper.reset()
    }
}

/// Non-continual PJRT model: window recompute every tick.
pub struct WindowModel {
    name: String,
    runner: WindowRunner,
}

impl WindowModel {
    pub fn load(rt: &Runtime, variant: &str) -> Result<Self> {
        let v = rt.load(variant)?;
        Ok(Self { name: variant.to_string(), runner: WindowRunner::new(v)? })
    }
}

impl StreamModel for WindowModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        &self.runner.variant().entry.family
    }
    fn config(&self) -> &ModelConfig {
        &self.runner.variant().entry.config
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        // window models take one token per tick: (B, 1, d_in) -> (B, d_in)
        let cfg = self.runner.variant().entry.config.clone();
        let t = HostTensor::new(vec![cfg.batch, cfg.d_in], tokens.data.clone())?;
        self.runner.tick(&t)
    }
    fn warm(&mut self, tokens: &HostTensor) -> Result<()> {
        let cfg = self.runner.variant().entry.config.clone();
        let t = HostTensor::new(vec![cfg.batch, cfg.d_in], tokens.data.clone())?;
        self.runner.push_only(&t)
    }
    fn reset(&mut self) -> Result<()> {
        self.runner.reset();
        Ok(())
    }
}

/// MAT-SED pipeline (Table III): a deep continual encoder whose
/// attended outputs feed a continual TransformerXL context net; the
/// coordinator chains the two executables per tick (DESIGN.md §5).
pub struct ChainedStepModel {
    name: String,
    enc: Stepper,
    ctx: Stepper,
}

impl ChainedStepModel {
    pub fn load(rt: &Runtime, enc_variant: &str, ctx_variant: &str) -> Result<Self> {
        let enc = Stepper::new(rt.load(enc_variant)?)?;
        let ctx = Stepper::new(rt.load(ctx_variant)?)?;
        let ec = &enc.variant().entry.config;
        let cc = &ctx.variant().entry.config;
        if ec.d_model != cc.d_in || ec.m_tokens != cc.m_tokens || ec.batch != cc.batch {
            bail!(
                "pipeline mismatch: enc (d={}, m={}, B={}) vs ctx (d_in={}, m={}, B={})",
                ec.d_model, ec.m_tokens, ec.batch, cc.d_in, cc.m_tokens, cc.batch
            );
        }
        Ok(Self { name: format!("{enc_variant}+{ctx_variant}"), enc, ctx })
    }
}

impl StreamModel for ChainedStepModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        "deepcot" // the continual pipeline's accounting family
    }
    fn config(&self) -> &ModelConfig {
        &self.enc.variant().entry.config
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        let mid = self.enc.tick(tokens)?;
        self.ctx.tick(&mid.out)
    }
    fn reset(&mut self) -> Result<()> {
        self.enc.reset()?;
        self.ctx.reset()
    }
}

/// Non-continual MAT-SED baseline: full encoder window recompute, then
/// the XL context window recomputed over the encoder's fresh outputs.
pub struct ChainedWindowModel {
    name: String,
    enc: WindowRunner,
    ctx: Rc<LoadedVariant>,
}

impl ChainedWindowModel {
    pub fn load(rt: &Runtime, enc_variant: &str, ctx_variant: &str) -> Result<Self> {
        let enc = WindowRunner::new(rt.load(enc_variant)?)?;
        let ctx = rt.load(ctx_variant)?;
        if ctx.entry.is_step() {
            bail!("{ctx_variant} must be a window variant");
        }
        let ec = &enc.variant().entry.config;
        let cc = &ctx.entry.config;
        if ec.d_model != cc.d_in || cc.window > ec.window || ec.batch != cc.batch {
            bail!("pipeline mismatch enc->ctx");
        }
        Ok(Self { name: format!("{enc_variant}+{ctx_variant}"), enc, ctx })
    }
}

impl StreamModel for ChainedWindowModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        "encoder"
    }
    fn config(&self) -> &ModelConfig {
        &self.enc.variant().entry.config
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        let ec = self.enc.variant().entry.config.clone();
        let t = HostTensor::new(vec![ec.batch, ec.d_in], tokens.data.clone())?;
        let mid = self.enc.tick(&t)?; // out: (B, n_enc, d)
        let cc = self.ctx.entry.config.clone();
        // feed the newest n_ctx encoder outputs into the context window
        let (b, n_enc, d) = (ec.batch, ec.window, ec.d_model);
        let n_ctx = cc.window;
        let mut win = vec![0.0f32; b * n_ctx * d];
        for lane in 0..b {
            let src = lane * n_enc * d + (n_enc - n_ctx) * d;
            let dst = lane * n_ctx * d;
            win[dst..dst + n_ctx * d]
                .copy_from_slice(&mid.out.data[src..src + n_ctx * d]);
        }
        let mut data = Vec::new();
        for spec in &self.ctx.entry.inputs {
            data.push(match spec.dtype.as_str() {
                "i32" => crate::runtime::DataInput::I32Scalar(0),
                _ => crate::runtime::DataInput::F32(HostTensor::new(
                    vec![b, n_ctx, d],
                    win.clone(),
                )?),
            });
        }
        let outs = self.ctx.execute(&data)?;
        let mut tensors = outs.tensors;
        let out = tensors.swap_remove(1);
        let logits = tensors.swap_remove(0);
        Ok(TickOut { logits, out })
    }
    fn reset(&mut self) -> Result<()> {
        self.enc.reset();
        Ok(())
    }
}

/// Pure-Rust scalar engine (the "standard implementation" CPU baseline)
/// — single-lane (B=1) continual DeepCoT over ring-buffer K/V memories.
pub struct ScalarModel {
    name: String,
    cfg: ModelConfig,
    inner: ScalarDeepCoT,
}

impl ScalarModel {
    pub fn load(rt: &Runtime, variant: &str) -> Result<Self> {
        let entry = rt.manifest().variant(variant)?.clone();
        if entry.family != "deepcot" {
            bail!("scalar engine implements the deepcot family only");
        }
        let params = ModelParams::load(rt.artifacts_dir(), &entry)?;
        Ok(Self::from_parts(format!("scalar:{variant}"), entry.config, params))
    }

    /// Build directly from config + params (synthetic benchmarks/tests
    /// that run without artifacts).
    pub fn from_parts(name: String, cfg: ModelConfig, params: ModelParams) -> Self {
        Self { name, cfg: cfg.clone(), inner: ScalarDeepCoT::new(cfg, params) }
    }
}

impl StreamModel for ScalarModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        "deepcot"
    }
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        anyhow::ensure!(self.cfg.batch == 1, "scalar engine is single-lane");
        let m = self.cfg.m_tokens;
        let t = Mat::from_vec(m, self.cfg.d_in, tokens.data.clone());
        let (logits, out) = self.inner.tick(&t)?;
        Ok(TickOut {
            logits: HostTensor::new(vec![1, self.cfg.n_classes], logits.to_vec())?,
            out: HostTensor::new(vec![1, m, self.cfg.d_model], out.data.clone())?,
        })
    }
    fn reset(&mut self) -> Result<()> {
        self.inner.reset();
        Ok(())
    }
}

/// Multi-lane scalar engine: B streams stepped through single stacked
/// shared-weight matmuls (`nn::batched`). The CPU twin of the batched
/// PJRT step variants, and the engine behind the coordinator's scalar
/// slot backend.
pub struct BatchedScalarModel {
    name: String,
    cfg: ModelConfig,
    inner: BatchedScalarDeepCoT,
}

impl BatchedScalarModel {
    pub fn load(rt: &Runtime, variant: &str) -> Result<Self> {
        let entry = rt.manifest().variant(variant)?.clone();
        if entry.family != "deepcot" {
            bail!("scalar engine implements the deepcot family only");
        }
        let params = ModelParams::load(rt.artifacts_dir(), &entry)?;
        Ok(Self::from_parts(format!("scalar-batched:{variant}"), entry.config, params))
    }

    pub fn from_parts(name: String, cfg: ModelConfig, params: ModelParams) -> Self {
        Self { name, cfg: cfg.clone(), inner: BatchedScalarDeepCoT::new(cfg, params) }
    }
}

impl StreamModel for BatchedScalarModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        "deepcot"
    }
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        let (b, m, d_in) = (self.inner.lanes(), self.cfg.m_tokens, self.cfg.d_in);
        anyhow::ensure!(
            tokens.data.len() == b * m * d_in,
            "batched scalar tick wants {} f32, got {}",
            b * m * d_in,
            tokens.data.len()
        );
        // (B, m, d_in) flattened is already lane-major stacked rows
        let t = Mat::from_vec(b * m, d_in, tokens.data.clone());
        let out = self.inner.tick_all(&t)?;
        Ok(TickOut {
            logits: HostTensor::new(vec![b, self.cfg.n_classes], out.logits.data.clone())?,
            out: HostTensor::new(vec![b, m, self.cfg.d_model], out.out.data.clone())?,
        })
    }
    fn reset(&mut self) -> Result<()> {
        self.inner.reset();
        Ok(())
    }
}

/// Pre-refactor scalar engine (flat memories rolled with `copy_within`,
/// fresh concatenations per tick) — kept only so benchmarks can report
/// the refactor's effect honestly. See `nn::naive`.
pub struct NaiveScalarModel {
    name: String,
    cfg: ModelConfig,
    inner: NaiveScalarDeepCoT,
}

impl NaiveScalarModel {
    pub fn from_parts(name: String, cfg: ModelConfig, params: ModelParams) -> Self {
        Self { name, cfg: cfg.clone(), inner: NaiveScalarDeepCoT::new(cfg, params) }
    }
}

impl StreamModel for NaiveScalarModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> &str {
        "deepcot"
    }
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn tick(&mut self, tokens: &HostTensor) -> Result<TickOut> {
        anyhow::ensure!(self.cfg.batch == 1, "naive scalar engine is single-lane");
        let m = self.cfg.m_tokens;
        let t = Mat::from_vec(m, self.cfg.d_in, tokens.data.clone());
        let (logits, out) = self.inner.tick(&t)?;
        Ok(TickOut {
            logits: HostTensor::new(vec![1, self.cfg.n_classes], logits)?,
            out: HostTensor::new(vec![1, m, self.cfg.d_model], out.data)?,
        })
    }
    fn reset(&mut self) -> Result<()> {
        self.inner.reset();
        Ok(())
    }
}
