//! URBAN-SED-like sound-event-detection streams (Table III workload):
//! spectrogram-frame tokens with overlapping multi-hot event labels and
//! onset/offset structure, so segment-based F1 and audio-tagging F1 are
//! both meaningful.

use crate::util::rng::Rng;
use crate::workload::{unit_direction, Corpus, StreamSample};

pub fn generate(
    rng: &mut Rng,
    n_clips: usize,
    t_len: usize,
    d_in: usize,
    n_events: usize,
) -> Corpus {
    assert!(n_events <= 32, "events encoded as u32 bitmask");
    let dirs: Vec<Vec<f32>> = (0..n_events).map(|_| unit_direction(rng, d_in)).collect();
    let rates: Vec<f32> = (0..n_events).map(|c| 0.15 + 0.5 * c as f32 / n_events as f32).collect();
    let mut samples = Vec::with_capacity(n_clips);
    for _ in 0..n_clips {
        let mut tokens = vec![0.0f32; t_len * d_in];
        let mut frame_events = vec![0u32; t_len];
        for v in tokens.iter_mut() {
            *v = rng.normal_f32() * 0.45; // urban background
        }
        let n_ev = rng.range(1, 5);
        for _ in 0..n_ev {
            let c = rng.below(n_events);
            let len = rng.range(t_len / 12 + 2, t_len / 3 + 3).min(t_len);
            let start = rng.below(t_len - len + 1);
            for t in start..start + len {
                let phase = (t - start) as f32 / len as f32;
                let env = (6.0 * phase.min(1.0 - phase)).min(1.0); // sharp on/offset
                let tex = (t as f32 * rates[c]).sin().abs();
                let row = &mut tokens[t * d_in..(t + 1) * d_in];
                for i in 0..d_in {
                    row[i] += (2.4 * env + 0.9 * env * tex) * dirs[c][i];
                }
                frame_events[t] |= 1 << c;
            }
        }
        // densest event as the single-label fallback
        let clip_label = (0..n_events)
            .max_by_key(|&c| frame_events.iter().filter(|&&m| m & (1 << c) != 0).count())
            .unwrap_or(0);
        let frame_labels = frame_events
            .iter()
            .map(|&m| if m == 0 { 0 } else { (m.trailing_zeros() + 1) as usize })
            .collect();
        samples.push(StreamSample {
            tokens,
            t_len,
            d_in,
            frame_labels,
            clip_label,
            frame_events,
        });
    }
    Corpus { samples, n_classes: n_events, d_in, name: "sed-urban".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_multi_hot() {
        let c = generate(&mut Rng::new(6), 10, 120, 16, 10);
        let any_overlap = c
            .samples
            .iter()
            .flat_map(|s| s.frame_events.iter())
            .any(|&m| m.count_ones() > 1);
        assert!(any_overlap, "expected at least one overlapping event frame");
        for s in &c.samples {
            assert_eq!(s.frame_events.len(), s.t_len);
        }
    }

    #[test]
    fn event_mask_matches_frame_label() {
        let c = generate(&mut Rng::new(7), 5, 60, 8, 6);
        for s in &c.samples {
            for t in 0..s.t_len {
                if s.frame_events[t] == 0 {
                    assert_eq!(s.frame_labels[t], 0);
                } else {
                    assert!(s.frame_labels[t] >= 1);
                }
            }
        }
    }
}
