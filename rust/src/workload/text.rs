//! GLUE-like token-stream classification (Table IV workload) and the
//! MNLI-stitched long streams of the Fig. 1 runtime sweep.
//!
//! Vocabulary = random embedding table. Each sample plants a 3-token
//! class motif at a controlled lag from the final (classification)
//! position. With lag beyond the attention window, only models with an
//! *extended effective receptive field* — DeepCoT's l(n-1) property —
//! can see the motif: this is the mechanism behind the paper's x0.5
//! window results, reproduced synthetically.

use crate::util::rng::Rng;
use crate::workload::{Corpus, StreamSample};

pub struct TextTask {
    pub vocab: Vec<Vec<f32>>,
    /// motif token ids per class (3 tokens each).
    pub motifs: Vec<[usize; 3]>,
    pub d_in: usize,
}

pub fn make_task(rng: &mut Rng, vocab_size: usize, d_in: usize, n_classes: usize) -> TextTask {
    let vocab: Vec<Vec<f32>> =
        (0..vocab_size).map(|_| rng.normal_vec(d_in, 1.0 / (d_in as f32).sqrt() * 4.0)).collect();
    let motifs = (0..n_classes)
        .map(|_| {
            [rng.below(vocab_size), rng.below(vocab_size), rng.below(vocab_size)]
        })
        .collect();
    TextTask { vocab, motifs, d_in }
}

/// Generate samples whose motif sits `lag` tokens before the end
/// (lag sampled in [lag_min, lag_max)).
pub fn generate(
    rng: &mut Rng,
    task: &TextTask,
    n_samples: usize,
    t_len: usize,
    lag_min: usize,
    lag_max: usize,
) -> Corpus {
    let n_classes = task.motifs.len();
    let d_in = task.d_in;
    let mut samples = Vec::with_capacity(n_samples);
    for i in 0..n_samples {
        let label = i % n_classes;
        let mut ids: Vec<usize> = (0..t_len).map(|_| rng.below(task.vocab.len())).collect();
        let lag = rng.range(lag_min, lag_max.max(lag_min + 1)).min(t_len - 3);
        let at = t_len - 3 - lag;
        ids[at..at + 3].copy_from_slice(&task.motifs[label]);
        let mut tokens = vec![0.0f32; t_len * d_in];
        for (t, &id) in ids.iter().enumerate() {
            tokens[t * d_in..(t + 1) * d_in].copy_from_slice(&task.vocab[id]);
            // small noise so embeddings are not bit-identical
            for v in tokens[t * d_in..(t + 1) * d_in].iter_mut() {
                *v += rng.normal_f32() * 0.05;
            }
        }
        samples.push(StreamSample {
            tokens,
            t_len,
            d_in,
            frame_labels: vec![label; t_len],
            clip_label: label,
            frame_events: Vec::new(),
        });
    }
    Corpus { samples, n_classes, d_in, name: "text-glue".into() }
}

/// Fig. 1 long-stream generator: stitch many segments into one stream
/// per batch lane (the paper stitches MNLI eval inputs into b groups
/// with separator tokens). Returns (T x d_in) rows per lane.
pub fn stitched_stream(rng: &mut Rng, task: &TextTask, t_len: usize) -> Vec<f32> {
    let d_in = task.d_in;
    let sep: Vec<f32> = vec![2.5; d_in]; // distinguished separator embedding
    let mut tokens = Vec::with_capacity(t_len * d_in);
    let mut until_sep = rng.range(8, 40);
    for _ in 0..t_len {
        if until_sep == 0 {
            tokens.extend_from_slice(&sep);
            until_sep = rng.range(8, 40);
        } else {
            let id = rng.below(task.vocab.len());
            tokens.extend_from_slice(&task.vocab[id]);
            until_sep -= 1;
        }
    }
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motif_planted_at_lag() {
        let mut rng = Rng::new(4);
        let task = make_task(&mut rng, 50, 8, 4);
        let c = generate(&mut rng, &task, 8, 64, 5, 6);
        for s in &c.samples {
            // motif should be at position t_len - 3 - 5
            let at = 64 - 3 - 5;
            let motif = &task.motifs[s.clip_label];
            for j in 0..3 {
                let emb = &task.vocab[motif[j]];
                let tok = s.token(at + j);
                let d: f32 = emb.iter().zip(tok).map(|(a, b)| (a - b) * (a - b)).sum();
                assert!(d < 0.5, "motif token {j} too far: {d}");
            }
        }
    }

    #[test]
    fn stitched_length() {
        let mut rng = Rng::new(5);
        let task = make_task(&mut rng, 20, 4, 2);
        let s = stitched_stream(&mut rng, &task, 100);
        assert_eq!(s.len(), 400);
    }
}
