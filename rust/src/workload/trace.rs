//! Arrival-process traces for serving experiments: open-loop load with
//! Poisson or bursty (two-state Markov-modulated) inter-arrival times,
//! plus deterministic replay — the stand-in for production request
//! traces (DESIGN.md §2).

use std::time::Duration;

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential inter-arrivals at `rate` tokens/s.
    Poisson { rate: f64 },
    /// Markov-modulated Poisson: switches between a calm and a burst
    /// rate; `p_switch` per arrival.
    Bursty { calm_rate: f64, burst_rate: f64, p_switch: f64 },
    /// Fixed-interval arrivals (sensor-like streams).
    Periodic { interval: Duration },
}

/// One generated trace event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    /// Which stream the token belongs to.
    pub stream: usize,
}

/// Generate a merged arrival trace for `n_streams` independent sources.
pub fn generate(
    rng: &mut Rng,
    process: ArrivalProcess,
    n_streams: usize,
    per_stream: usize,
) -> Vec<Arrival> {
    let mut events = Vec::with_capacity(n_streams * per_stream);
    for s in 0..n_streams {
        let mut t = 0.0f64;
        let mut bursting = false;
        let mut r = rng.fork();
        for _ in 0..per_stream {
            let dt = match process {
                ArrivalProcess::Poisson { rate } => exp_sample(&mut r, rate),
                ArrivalProcess::Bursty { calm_rate, burst_rate, p_switch } => {
                    if r.chance(p_switch) {
                        bursting = !bursting;
                    }
                    exp_sample(&mut r, if bursting { burst_rate } else { calm_rate })
                }
                ArrivalProcess::Periodic { interval } => interval.as_secs_f64(),
            };
            t += dt;
            events.push(Arrival { at: Duration::from_secs_f64(t), stream: s });
        }
    }
    events.sort_by_key(|e| e.at);
    events
}

fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    debug_assert!(rate > 0.0);
    let u = rng.uniform().max(f64::MIN_POSITIVE);
    -u.ln() / rate
}

/// Summary statistics of a trace (for EXPERIMENTS.md reporting).
#[derive(Debug, Clone, Copy)]
pub struct TraceStats {
    pub events: usize,
    pub span: Duration,
    pub mean_rate: f64,
    /// peak rate over 100ms buckets
    pub peak_rate: f64,
}

pub fn stats(trace: &[Arrival]) -> TraceStats {
    if trace.is_empty() {
        return TraceStats {
            events: 0,
            span: Duration::ZERO,
            mean_rate: 0.0,
            peak_rate: 0.0,
        };
    }
    let span = trace.last().unwrap().at;
    let bucket = 0.1f64;
    let n_buckets = (span.as_secs_f64() / bucket).ceil().max(1.0) as usize;
    let mut counts = vec![0usize; n_buckets];
    for e in trace {
        let b = ((e.at.as_secs_f64() / bucket) as usize).min(n_buckets - 1);
        counts[b] += 1;
    }
    TraceStats {
        events: trace.len(),
        span,
        mean_rate: trace.len() as f64 / span.as_secs_f64().max(1e-9),
        peak_rate: counts.iter().copied().max().unwrap_or(0) as f64 / bucket,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn poisson_rate_approximates_target() {
        let mut rng = Rng::new(8);
        let trace = generate(&mut rng, ArrivalProcess::Poisson { rate: 100.0 }, 1, 5000);
        let s = stats(&trace);
        assert!((s.mean_rate - 100.0).abs() < 8.0, "rate {}", s.mean_rate);
    }

    #[test]
    fn merged_trace_is_sorted_and_complete() {
        let mut rng = Rng::new(9);
        let trace = generate(&mut rng, ArrivalProcess::Poisson { rate: 50.0 }, 4, 100);
        assert_eq!(trace.len(), 400);
        assert!(trace.windows(2).all(|w| w[0].at <= w[1].at));
        for s in 0..4 {
            assert_eq!(trace.iter().filter(|e| e.stream == s).count(), 100);
        }
    }

    #[test]
    fn periodic_is_exact() {
        let mut rng = Rng::new(10);
        let trace = generate(
            &mut rng,
            ArrivalProcess::Periodic { interval: Duration::from_millis(10) },
            1,
            10,
        );
        assert_eq!(trace[9].at, Duration::from_millis(100));
    }

    #[test]
    fn bursty_has_higher_peak_than_poisson_at_same_mean() {
        let mut rng = Rng::new(11);
        let bursty = generate(
            &mut rng,
            ArrivalProcess::Bursty { calm_rate: 20.0, burst_rate: 500.0, p_switch: 0.02 },
            1,
            4000,
        );
        let sb = stats(&bursty);
        let poisson = generate(
            &mut rng,
            ArrivalProcess::Poisson { rate: sb.mean_rate },
            1,
            4000,
        );
        let sp = stats(&poisson);
        assert!(
            sb.peak_rate / sb.mean_rate > sp.peak_rate / sp.mean_rate,
            "bursty peak/mean {} vs poisson {}",
            sb.peak_rate / sb.mean_rate,
            sp.peak_rate / sp.mean_rate
        );
    }

    /// Property: traces are deterministic per seed and event counts are
    /// always exactly n_streams * per_stream.
    #[test]
    fn prop_trace_determinism() {
        prop::check("trace-determinism", 50, |rng| {
            let seed = rng.next_u64();
            let n = rng.range(1, 5);
            let k = rng.range(1, 50);
            let a = generate(&mut Rng::new(seed), ArrivalProcess::Poisson { rate: 30.0 }, n, k);
            let b = generate(&mut Rng::new(seed), ArrivalProcess::Poisson { rate: 30.0 }, n, k);
            if a != b {
                return Err("trace not deterministic".into());
            }
            if a.len() != n * k {
                return Err(format!("expected {} events, got {}", n * k, a.len()));
            }
            Ok(())
        });
    }
}
