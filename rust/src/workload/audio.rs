//! GTZAN-like audio classification clips (Table II workload).
//!
//! Each genre c is a stationary texture: an AR(1) process along time
//! whose innovation is shaped by a class covariance (two signature
//! directions + class-specific oscillation rates) — VGGish-token
//! stand-ins where the *whole clip* carries the label, matching
//! clip-level audio classification.

use crate::util::rng::Rng;
use crate::workload::{unit_direction, Corpus, StreamSample};

pub fn generate(
    rng: &mut Rng,
    n_clips: usize,
    t_len: usize,
    d_in: usize,
    n_classes: usize,
) -> Corpus {
    struct Genre {
        dir_a: Vec<f32>,
        dir_b: Vec<f32>,
        /// constant timbre axis — a genre's stationary spectral tilt
        dir_c: Vec<f32>,
        rho: f32,
        omega: f32,
    }
    let genres: Vec<Genre> = (0..n_classes)
        .map(|c| Genre {
            dir_a: unit_direction(rng, d_in),
            dir_b: unit_direction(rng, d_in),
            dir_c: unit_direction(rng, d_in),
            rho: 0.55 + 0.4 * (c as f32 / n_classes.max(1) as f32),
            omega: 0.25 + 0.6 * (c as f32 / n_classes.max(1) as f32),
        })
        .collect();
    let mut samples = Vec::with_capacity(n_clips);
    for i in 0..n_clips {
        let label = i % n_classes; // balanced classes
        let g = &genres[label];
        let mut tokens = vec![0.0f32; t_len * d_in];
        let mut state = vec![0.0f32; d_in];
        for t in 0..t_len {
            let osc = (t as f32 * g.omega).sin();
            for i in 0..d_in {
                let innov = rng.normal_f32() * 0.6
                    + 1.2 * osc * g.dir_a[i]
                    + 0.8 * (1.0 - osc * osc) * g.dir_b[i]
                    + 0.5 * g.dir_c[i];
                state[i] = g.rho * state[i] + (1.0 - g.rho) * innov;
                tokens[t * d_in + i] = state[i] + rng.normal_f32() * 0.55;
            }
        }
        samples.push(StreamSample {
            tokens,
            t_len,
            d_in,
            frame_labels: vec![label; t_len],
            clip_label: label,
            frame_events: Vec::new(),
        });
    }
    Corpus { samples, n_classes, d_in, name: "audio-gtzan".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_labels() {
        let c = generate(&mut Rng::new(1), 20, 30, 8, 10);
        let mut counts = vec![0; 10];
        for s in &c.samples {
            counts[s.clip_label] += 1;
        }
        assert!(counts.iter().all(|&n| n == 2));
    }

    #[test]
    fn classes_are_separable_by_mean_feature() {
        let c = generate(&mut Rng::new(2), 40, 120, 16, 2);
        // mean token per class should differ measurably
        let mean_of = |cls: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; 16];
            let mut n = 0;
            for s in c.samples.iter().filter(|s| s.clip_label == cls) {
                for t in 0..s.t_len {
                    for (a, &v) in acc.iter_mut().zip(s.token(t)) {
                        *a += v;
                    }
                    n += 1;
                }
            }
            acc.iter_mut().for_each(|a| *a /= n as f32);
            acc
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let dist: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(dist > 1e-3, "class means too close: {dist}");
    }
}
