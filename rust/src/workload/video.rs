//! THUMOS14-like online-action-detection streams (Table I workload).
//!
//! Long feature streams (TSN-feature stand-ins) alternating background
//! noise with action segments. Each action class c has a signature
//! direction u_c and a characteristic temporal envelope (ramp up, hold,
//! ramp down) — so detecting an action *early* (the OAD objective)
//! benefits from temporal context, which is exactly what the attention
//! window provides.

use crate::util::rng::Rng;
use crate::workload::{unit_direction, Corpus, StreamSample};

/// `n_classes` are action classes 1..=n_classes; frame label 0 means
/// background. Clip label = most frequent action in the stream.
pub fn generate(
    rng: &mut Rng,
    n_streams: usize,
    t_len: usize,
    d_in: usize,
    n_classes: usize,
) -> Corpus {
    let dirs: Vec<Vec<f32>> = (0..n_classes).map(|_| unit_direction(rng, d_in)).collect();
    // secondary direction per class: the "motion" axis modulated in time
    let dirs2: Vec<Vec<f32>> = (0..n_classes).map(|_| unit_direction(rng, d_in)).collect();
    let mut samples = Vec::with_capacity(n_streams);
    for _ in 0..n_streams {
        let mut tokens = vec![0.0f32; t_len * d_in];
        let mut frame_labels = vec![0usize; t_len];
        // background texture
        for v in tokens.iter_mut() {
            *v = rng.normal_f32() * 0.6;
        }
        // plant 1..4 action segments
        let mut counts = vec![0usize; n_classes + 1];
        let n_seg = rng.range(1, 5);
        for _ in 0..n_seg {
            let c = rng.below(n_classes);
            let len = rng.range(t_len / 10 + 2, t_len / 3 + 3).min(t_len);
            let start = rng.below(t_len - len + 1);
            for t in start..start + len {
                let phase = (t - start) as f32 / len as f32;
                // envelope: ramp-hold-ramp
                let env = (4.0 * phase.min(1.0 - phase)).min(1.0);
                let wob = (phase * std::f32::consts::PI * 3.0).sin();
                let row = &mut tokens[t * d_in..(t + 1) * d_in];
                for i in 0..d_in {
                    row[i] += 2.8 * env * dirs[c][i] + 1.4 * env * wob * dirs2[c][i];
                }
                frame_labels[t] = c + 1;
                counts[c + 1] += 1;
            }
        }
        let clip_label = counts
            .iter()
            .enumerate()
            .skip(1)
            .max_by_key(|(_, &n)| n)
            .map(|(c, _)| c)
            .unwrap_or(0);
        samples.push(StreamSample {
            tokens,
            t_len,
            d_in,
            frame_labels,
            clip_label,
            frame_events: Vec::new(),
        });
    }
    Corpus { samples, n_classes: n_classes + 1, d_in, name: "video-oad".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_and_labels() {
        let c = generate(&mut Rng::new(3), 5, 80, 16, 20);
        assert_eq!(c.samples.len(), 5);
        for s in &c.samples {
            assert_eq!(s.tokens.len(), 80 * 16);
            assert_eq!(s.frame_labels.len(), 80);
            assert!(s.clip_label <= 20);
            assert!(s.frame_labels.iter().any(|&l| l > 0), "some action planted");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&mut Rng::new(7), 2, 40, 8, 4);
        let b = generate(&mut Rng::new(7), 2, 40, 8, 4);
        assert_eq!(a.samples[0].tokens, b.samples[0].tokens);
    }

    #[test]
    fn action_frames_have_signal() {
        let c = generate(&mut Rng::new(5), 20, 100, 32, 6);
        // mean norm of action frames should exceed background frames
        let (mut act, mut bg, mut na, mut nb) = (0.0f64, 0.0f64, 0, 0);
        for s in &c.samples {
            for t in 0..s.t_len {
                let e: f32 = s.token(t).iter().map(|x| x * x).sum();
                if s.frame_labels[t] > 0 {
                    act += e as f64;
                    na += 1;
                } else {
                    bg += e as f64;
                    nb += 1;
                }
            }
        }
        assert!(act / na as f64 > bg / nb as f64);
    }
}
