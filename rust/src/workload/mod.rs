//! Synthetic stream corpora — the stand-ins for THUMOS14 / GTZAN /
//! URBAN-SED / GLUE (substitution table, DESIGN.md §2).
//!
//! Every generator plants class-dependent *temporal* structure so the
//! downstream encoder + linear probe pipeline has signal to recover:
//! accuracy columns then order model variants the same way a real
//! dataset would (who wins / loses with a limited attention window),
//! while token counts and dimensions match the paper's geometry.

pub mod audio;
pub mod sed;
pub mod text;
pub mod trace;
pub mod video;

use crate::util::rng::Rng;

/// One labeled stream (a "clip" in the paper's datasets).
#[derive(Debug, Clone)]
pub struct StreamSample {
    /// Row-major (t_len x d_in) token features.
    pub tokens: Vec<f32>,
    pub t_len: usize,
    pub d_in: usize,
    /// Per-frame single label (class index; 0 = background for OAD/SED).
    pub frame_labels: Vec<usize>,
    /// Clip-level label.
    pub clip_label: usize,
    /// Per-frame multi-hot event mask (SED only; bit c = event c active).
    pub frame_events: Vec<u32>,
}

impl StreamSample {
    pub fn token(&self, t: usize) -> &[f32] {
        &self.tokens[t * self.d_in..(t + 1) * self.d_in]
    }
}

/// A corpus of labeled streams plus its label-space metadata.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub samples: Vec<StreamSample>,
    pub n_classes: usize,
    pub d_in: usize,
    pub name: String,
}

impl Corpus {
    /// Deterministic train/eval split (by index parity buckets).
    pub fn split(&self, train_frac: f64) -> (Vec<&StreamSample>, Vec<&StreamSample>) {
        let n_train = (self.samples.len() as f64 * train_frac).round() as usize;
        let train = self.samples.iter().take(n_train).collect();
        let eval = self.samples.iter().skip(n_train).collect();
        (train, eval)
    }
}

/// Shared helper: unit-norm random direction.
pub(crate) fn unit_direction(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = rng.normal_vec(d, 1.0);
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
    v.iter_mut().for_each(|x| *x /= norm);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions() {
        let c = video::generate(&mut Rng::new(1), 10, 40, 8, 4);
        let (tr, ev) = c.split(0.7);
        assert_eq!(tr.len() + ev.len(), 10);
        assert_eq!(tr.len(), 7);
    }

    #[test]
    fn unit_direction_normed() {
        let mut rng = Rng::new(2);
        let v = unit_direction(&mut rng, 32);
        let n: f32 = v.iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 1e-4);
    }
}
