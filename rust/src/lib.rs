//! DeepCoT — Deep Continual Transformers for real-time inference on data
//! streams (Carreto Picón et al., 2025), reproduced as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! Layer 3 (this crate) owns the request path: stream sessions, slot-based
//! continual batching, the tick scheduler, and per-stream Key/Value
//! memories held as PJRT buffers. Layers 2/1 (JAX model + Pallas kernels)
//! run only at build time (`make artifacts`) and ship as AOT-compiled HLO
//! text loaded by [`runtime`].
//!
//! Quick tour:
//! - [`runtime`] — PJRT client, manifest-driven executable loading,
//!   continual [`runtime::Stepper`]s with device-resident state.
//! - [`coordinator`] — the serving engine: RAII stream sessions over
//!   typed errors, router, slot batcher, tick scheduler, pluggable
//!   `StreamBackend`s with portable stream-state snapshots, live
//!   cross-shard migration, metrics.
//! - [`net`] — the TCP front door: length-prefixed binary wire
//!   protocol, a readiness-loop executor server (one poll thread plus
//!   a fixed worker pool, one engine `Session` per client stream, with
//!   connection limits, stream quotas, and optional shared-token OPEN
//!   auth), and a pipelining client; `bin/deepcot_serve` is the CLI.
//! - [`obs`] — production observability: tick-pipeline stage spans,
//!   Prometheus/JSON exposition (HTTP endpoint + wire frame), windowed
//!   rates, and a bounded structured event journal, all behind the
//!   `obs` level knob.
//! - [`store`] — durable stream-state storage: a versioned checksummed
//!   codec for hibernated stream records plus the [`store::StateStore`]
//!   trait (in-memory and log-structured single-file disk impls) that
//!   stream hibernation and `deepcot_serve --state-dir` crash recovery
//!   run on.
//! - [`fault`] — deterministic, seeded fault injection (shard panics,
//!   store I/O errors, net failures, torn log tails) behind
//!   `EngineConfig::fault` / `DEEPCOT_FAULT`; the chaos harness the
//!   shard supervisor and degraded store mode are tested under.
//! - [`baselines`] — the paper's comparison systems behind one
//!   [`baselines::StreamModel`] trait (regular encoder, Continual
//!   Transformer, Nyströmformer, FNet, DeepCoT, DeepCoT-XL, MAT-SED
//!   pipeline).
//! - [`nn`] — pure-Rust scalar reference engine (oracle + CPU baseline):
//!   ring-buffer K/V memories and batched multi-lane stepping with zero
//!   steady-state allocation; also the coordinator's fallback backend
//!   when the XLA shared library is absent.
//! - [`flops`] — the paper's analytic FLOPs accounting.
//! - [`workload`] — synthetic stream corpora standing in for THUMOS14 /
//!   GTZAN / URBAN-SED / GLUE (DESIGN.md §2).
//! - [`probe`] — ridge/logistic readouts + metrics (accuracy, mAP, F1).
//! - [`bench_harness`] — regenerates every paper table and figure.
//! - [`synthetic`] — hermetic synthetic serve artifacts (manifest +
//!   weights blob) for engine/cluster tests and `bench_throughput`.

// Numeric kernels index with explicit offsets on purpose (mirrors the
// papers' loop nests and keeps summation order auditable).
#![allow(clippy::needless_range_loop)]

pub mod baselines;
pub mod util;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
#[deny(missing_docs)]
pub mod fault;
pub mod flops;
pub mod manifest;
#[deny(missing_docs)]
pub mod net;
pub mod nn;
#[deny(missing_docs)]
pub mod obs;
pub mod probe;
pub mod runtime;
#[deny(missing_docs)]
pub mod store;
pub mod synthetic;
pub mod workload;

/// Locate the artifacts directory: `$DEEPCOT_ARTIFACTS` or
/// `<crate root>/artifacts` (the `make artifacts` output).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DEEPCOT_ARTIFACTS") {
        return p.into();
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
