//! Steady-state allocation audit for the continual hot path.
//!
//! A counting global allocator wraps the system allocator; after warmup
//! the scalar and batched steppers must tick with ZERO heap allocations
//! (the ring-buffer + scratch-workspace design's core guarantee, and
//! what keeps the "standard implementation" CPU baseline's latency a
//! measurement of the algorithm rather than of the allocator).
//!
//! This file holds a single #[test] so no sibling test thread can
//! pollute the counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use deepcot::coordinator::metrics::LatencyHisto;
use deepcot::manifest::ModelConfig;
use deepcot::net::proto::{self, RawFrame};
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::encoder::ScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::simd::KernelOps;
use deepcot::nn::tensor::Mat;
use deepcot::obs::expo::{RateSample, SnapshotRing};
use deepcot::obs::journal::{EventKind, Journal};
use deepcot::obs::span::{Stage, StageSpans};
use deepcot::store::codec::StreamRecord;
use deepcot::util::rng::Rng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bench_cfg() -> ModelConfig {
    // d=32 / 4 heads / depth 4 / window 32 (d_in 16, m=1 defaults)
    ModelConfig::synthetic(32, 4, 4, 32)
}

#[test]
fn steady_state_ticks_allocate_nothing() {
    let cfg = bench_cfg();
    let params = ModelParams::synthetic(&cfg, &mut Rng::new(13));

    // single-lane ring stepper (depth 4, window 32)
    let mut eng = ScalarDeepCoT::new(cfg.clone(), params.clone());
    let tokens = Mat::from_vec(1, cfg.d_in, Rng::new(19).normal_vec(cfg.d_in, 1.0));
    for _ in 0..3 {
        eng.tick(&tokens).unwrap();
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    let mut sink = 0.0f32;
    for _ in 0..5 {
        let (logits, out) = eng.tick(&tokens).unwrap();
        sink += logits[0] + out.at(0, 0);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "ScalarDeepCoT::tick allocated {} times across 5 steady-state ticks",
        after - before
    );

    // batched 4-lane stepper with a masked lane (slot-stepper regime);
    // the caller owns the per-lane position clocks, advancing only the
    // lanes it ticked live — all in preallocated storage
    let mut batched = BatchedScalarDeepCoT::with_lanes(cfg.clone(), params, 4);
    let stacked = Mat::from_vec(4, cfg.d_in, Rng::new(23).normal_vec(4 * cfg.d_in, 1.0));
    let live = [true, false, true, true];
    let mut pos = [0i32; 4];
    let advance = |pos: &mut [i32; 4]| {
        for (p, l) in pos.iter_mut().zip(&live) {
            *p += *l as i32;
        }
    };
    for _ in 0..3 {
        batched.tick_lanes(&stacked, &live, &pos).unwrap();
        advance(&mut pos);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        let step = batched.tick_lanes(&stacked, &live, &pos).unwrap();
        sink += step.logits.at(0, 0) + step.out.at(0, 0);
        advance(&mut pos);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "BatchedScalarDeepCoT::tick_lanes allocated {} times across 5 steady-state ticks",
        after - before
    );
    assert!(sink.is_finite());

    // stream-state snapshots reuse their buffers: after the first
    // export establishes capacity, export → import → tick cycles stay
    // allocation-free (a migration can't perturb the steady state)
    let (mut data, mut heads) = (Vec::new(), Vec::new());
    batched.export_lane(0, &mut data, &mut heads);
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        batched.export_lane(0, &mut data, &mut heads);
        batched.import_lane(2, &data, &heads).unwrap();
        let step = batched.tick_lanes(&stacked, &live, &pos).unwrap();
        sink += step.logits.at(0, 0);
        advance(&mut pos);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "snapshot export/import allocated {} times across 5 reused-buffer cycles",
        after - before
    );
    assert!(sink.is_finite());

    // packed-kernel remainder paths + RopeTable steady state: a
    // geometry whose d_head (10) is not a multiple of the 8-wide
    // unroll, with multi-token ticks so the ring write head lands at
    // varied mid-buffer offsets. The packing pass and the rope-row
    // memo storage are built at construction; steady-state ticks must
    // compute sin/cos rows and remainder-lane dots entirely in place.
    let mut odd_cfg = ModelConfig::synthetic(20, 2, 2, 9);
    odd_cfg.m_tokens = 2;
    let odd_params = ModelParams::synthetic(&odd_cfg, &mut Rng::new(29));
    let mut odd = BatchedScalarDeepCoT::with_lanes(odd_cfg.clone(), odd_params, 3);
    let odd_toks =
        Mat::from_vec(3 * 2, odd_cfg.d_in, Rng::new(31).normal_vec(3 * 2 * odd_cfg.d_in, 1.0));
    for _ in 0..4 {
        odd.tick_all(&odd_toks).unwrap();
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        let step = odd.tick_all(&odd_toks).unwrap();
        sink += step.logits.at(0, 0) + step.out.at(0, 0);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "odd-geometry packed-kernel tick allocated {} times across 5 steady-state ticks",
        after - before
    );
    assert!(sink.is_finite());

    // explicit-SIMD dispatch steady state: the same odd geometry
    // forced onto the best native path (`KernelOps::native` — AVX2 /
    // NEON where available, the scalar table otherwise, so this
    // section never goes vacuous). The SIMD kernels spill their
    // accumulators to stack arrays and write through the caller's
    // slices — dispatch must not cost a single heap allocation per
    // tick any more than the scalar path does.
    let odd_params = ModelParams::synthetic(&odd_cfg, &mut Rng::new(29));
    let mut simd =
        BatchedScalarDeepCoT::with_lanes_ops(odd_cfg.clone(), odd_params, 3, KernelOps::native());
    for _ in 0..4 {
        simd.tick_all(&odd_toks).unwrap();
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        let step = simd.tick_all(&odd_toks).unwrap();
        sink += step.logits.at(0, 0) + step.out.at(0, 0);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "native-SIMD ({}) tick allocated {} times across 5 steady-state ticks",
        simd.dispatch(),
        after - before
    );
    assert!(sink.is_finite());

    // net wire codec steady state: the serialization layer of the TCP
    // front door's PUSH → TICK loop — encode into reused frame
    // buffers, decode into reused scratch vectors — performs ZERO
    // allocations after warmup. Scope is the CODEC, pinned in
    // isolation: the server's full reply loop still allocates once
    // per push by engine-API design (`Session::push` consumes an
    // owned Vec<f32>, and each mpsc reply message is a heap node);
    // those are engine costs, not codec regressions, and this test
    // keeps the codec from quietly adding to them. The buffers below
    // are exactly what the executor's per-connection read buffer /
    // write queue and the client hot path hold.
    let tokens = Rng::new(37).normal_vec(16, 1.0);
    let logits = Rng::new(41).normal_vec(4, 1.0);
    let acts = Rng::new(43).normal_vec(32, 1.0);
    let (mut push_buf, mut tick_buf) = (Vec::new(), Vec::new());
    let (mut tok_scratch, mut logit_scratch, mut act_scratch) =
        (Vec::new(), Vec::new(), Vec::new());
    let mut codec_cycle = |i: u64, sink: &mut f32| {
        proto::write_push(&mut push_buf, 7, &tokens);
        let raw = RawFrame::parse(&push_buf[4..]).unwrap();
        let stream = raw.push_fields_into(&mut tok_scratch).unwrap();
        proto::write_tick(&mut tick_buf, stream, i + 1, &logits, &acts);
        let raw = RawFrame::parse(&tick_buf[4..]).unwrap();
        let (s2, t2) = raw.tick_fields_into(&mut logit_scratch, &mut act_scratch).unwrap();
        *sink += tok_scratch[0] + logit_scratch[0] + act_scratch[0] + (s2 + t2) as f32;
    };
    for i in 0..3 {
        codec_cycle(i, &mut sink); // warmup establishes buffer capacity
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..5 {
        codec_cycle(i, &mut sink);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state PUSH/TICK codec round trips allocated {} times across 5 cycles",
        after - before
    );
    assert!(sink.is_finite());

    // hibernation steady state: with hibernation enabled, ticking an
    // *active* stream must cost exactly what it costs without it — the
    // pool is consulted on open/wake/close only, never on the tick
    // path, so the sections above already pin that side. What IS new
    // per snapshot period is the store codec: `HibernatePool`
    // checkpoints by `encode_into` a reused buffer, and restore decodes
    // with `decode_into` into a warm record. After one warmup cycle
    // establishes the capacities, that whole persist/restore round
    // trip must be allocation-free — a periodic snapshot may not
    // perturb the steady state it is checkpointing.
    let make_rec = |seed: u64| {
        let mut r = Rng::new(seed);
        StreamRecord {
            stream: 7,
            ticks: 40,
            pos: 40,
            write_heads: (0..4).map(|_| r.below(64)).collect(),
            kv_rings: r.normal_vec(256, 1.0),
            queued: vec![r.normal_vec(16, 1.0), r.normal_vec(16, 1.0)],
        }
    };
    let rec = make_rec(47);
    let mut blob = Vec::new();
    let mut warm = make_rec(53); // same shape, different contents
    rec.encode_into(&mut blob);
    warm.decode_into(&blob).unwrap();
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        rec.encode_into(&mut blob);
        warm.decode_into(&blob).unwrap();
        sink += warm.kv_rings[0] + warm.queued[0][0];
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "store codec allocated {} times across 5 reused-buffer checkpoint cycles",
        after - before
    );
    assert!(sink.is_finite());

    // observability primitives: everything the instrumentation touches
    // per tick (stage-span records, slow-tick journal pushes past ring
    // capacity, rate-ring samples, in-place histogram resets) must be
    // allocation-free once warmed — `obs=spans` may not perturb the
    // steady state it observes (CI runs this suite with DEEPCOT_OBS
    // forced to `spans`)
    let mut spans = StageSpans::new();
    let journal = Journal::with_limits(8, 1_000_000);
    let mut ring = SnapshotRing::new(4);
    let mut histo = LatencyHisto::new();
    for i in 0..12u64 {
        // warm past both ring capacities so pushes rotate, not grow
        journal.push(EventKind::SlowTick, i, 0, i);
        ring.push(RateSample { t_us: i * 1000, ticks: i, ..RateSample::default() });
    }
    histo.record(Duration::from_micros(5));
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..20u64 {
        spans.record(Stage::BackendStep, Duration::from_micros(i + 1));
        spans.record(Stage::PipelineTotal, Duration::from_micros(i + 2));
        journal.push(EventKind::SlowTick, i, 0, i);
        let sample = RateSample { t_us: (12 + i) * 1000, ticks: 12 + i, ..RateSample::default() };
        let rates = ring.rates_against(&sample, Duration::from_secs(10));
        ring.push(sample);
        sink += rates.ticks_per_sec as f32;
        histo.record(Duration::from_micros(i + 1));
        histo.reset();
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "obs primitives allocated {} times across 20 warmed record/push/reset cycles",
        after - before
    );
    assert_eq!(spans.get(Stage::BackendStep).count(), 20);
    assert_eq!(journal.len(), 8, "journal must stay bounded at capacity");
    assert!(sink.is_finite());
}
