//! SIMD ≡ scalar, bitwise: every kernel in the `nn::simd` dispatch
//! tables pinned against its scalar twin **bit for bit**, per kernel
//! and end to end, across the same odd-geometry matrix
//! `tests/kernels_equiv.rs` sweeps (remainder lanes via `d_head` ∉ 8ℤ,
//! mid-wrap two-segment rings via every split, lane counts 1/3/5).
//!
//! This is the contract that lets dispatch be chosen per machine while
//! every bitwise cluster pin (1-shard ≡ 4-shard, migration
//! transparency, TCP-trace identity, lane snapshot roundtrips) keeps
//! holding: scalar vs SIMD is *not* a tolerance relationship — the
//! SIMD kernels reproduce the exact fixed-summation-order op sequence
//! (see the determinism policy in `nn::kernels` and `nn::simd`), so
//! equality here is `to_bits()` throughout.
//!
//! Every test iterates [`simd_paths`] — the non-scalar tables this
//! build/CPU can actually run (AVX2 on x86_64 with the feature, NEON
//! on aarch64). On a machine with no SIMD path the sweeps are vacuous
//! and [`native_path_is_covered`] documents that that is because
//! native dispatch is scalar there, not because coverage silently
//! narrowed.

use deepcot::manifest::ModelConfig;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::kernels::{residual_fused, PackedLinear};
use deepcot::nn::params::{ModelParams, Norm};
use deepcot::nn::rope::RopeTable;
use deepcot::nn::simd::{DispatchChoice, DispatchPath, KernelOps};
use deepcot::nn::tensor::Mat;
use deepcot::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every non-scalar dispatch table this build/CPU can run. Explicit
/// resolution ignores `DEEPCOT_KERNEL_DISPATCH`, so these tests
/// exercise the SIMD kernels even under a scalar-forced test
/// environment (the CI scalar leg).
fn simd_paths() -> Vec<&'static KernelOps> {
    [DispatchChoice::Avx2, DispatchChoice::Neon]
        .into_iter()
        .filter_map(|c| KernelOps::resolve(c).ok())
        .collect()
}

/// If native dispatch resolves to a SIMD path, that path must be in
/// the set the sweeps below cover — the guard that keeps the vacuous
/// no-SIMD-machine case honest.
#[test]
fn native_path_is_covered() {
    let native = KernelOps::native();
    if native.path != DispatchPath::Scalar {
        assert!(
            simd_paths().iter().any(|o| o.path == native.path),
            "native path {} missing from the swept set",
            native.path
        );
    }
}

/// Reductions: `dot` / `sqdist` bit-identical through several unroll
/// multiples and every remainder length (the 8 SIMD lanes must BE the
/// 8 scalar split accumulators, reduced by the same pairwise tree).
#[test]
fn dot_and_sqdist_are_bitwise_across_paths() {
    let scalar = KernelOps::scalar();
    for ops in simd_paths() {
        let mut rng = Rng::new(201);
        for len in (0..=40).chain([64, 100]) {
            let a = rng.normal_vec(len, 1.0);
            let b = rng.normal_vec(len, 1.0);
            assert_eq!(
                (ops.dot)(&a, &b).to_bits(),
                (scalar.dot)(&a, &b).to_bits(),
                "{} dot len {len}",
                ops.path
            );
            assert_eq!(
                (ops.sqdist)(&a, &b).to_bits(),
                (scalar.sqdist)(&a, &b).to_bits(),
                "{} sqdist len {len}",
                ops.path
            );
        }
    }
}

/// Elementwise kernels: `axpy` / `add_assign` have no reduction, but
/// the per-lane op sequence must still be mul-then-add (no FMA) for
/// the bits to match.
#[test]
fn elementwise_kernels_are_bitwise_across_paths() {
    let scalar = KernelOps::scalar();
    for ops in simd_paths() {
        let mut rng = Rng::new(202);
        for len in 0..=40 {
            let x = rng.normal_vec(len, 1.0);
            let y0 = rng.normal_vec(len, 1.0);
            let mut want = y0.clone();
            (scalar.axpy)(0.37, &x, &mut want);
            let mut got = y0.clone();
            (ops.axpy)(0.37, &x, &mut got);
            assert_eq!(bits(&got), bits(&want), "{} axpy len {len}", ops.path);
            let mut want = y0.clone();
            (scalar.add_assign)(&mut want, &x);
            let mut got = y0;
            (ops.add_assign)(&mut got, &x);
            assert_eq!(bits(&got), bits(&want), "{} add_assign len {len}", ops.path);
        }
    }
}

/// Packed fused matmul+bias: same weights packed onto each path, all
/// three forward entries bit-identical across shapes that exercise
/// full-chunk and remainder dot paths (incl. the `(6, 10)` remainder
/// pair and a `k > 32` shape).
#[test]
fn packed_linear_is_bitwise_across_paths() {
    for ops in simd_paths() {
        let mut rng = Rng::new(203);
        for (k, c) in [(1usize, 1usize), (5, 3), (6, 10), (8, 8), (10, 4), (33, 7), (64, 10)] {
            let w = Mat::from_vec(k, c, rng.normal_vec(k * c, 1.0));
            let bias = rng.normal_vec(c, 0.5);
            let x = Mat::from_vec(3, k, rng.normal_vec(3 * k, 1.0));
            let scalar = PackedLinear::pack_with(&w, &bias, KernelOps::scalar());
            let simd = PackedLinear::pack_with(&w, &bias, ops);
            let mut want = Mat::zeros(3, c);
            scalar.forward_into(&x, &mut want);
            let mut got = Mat::zeros(3, c);
            simd.forward_into(&x, &mut got);
            assert_eq!(bits(&got.data), bits(&want.data), "{} linear {k}x{c}", ops.path);
            let mut want_g = Mat::zeros(3, c);
            scalar.forward_gelu_into(&x, &mut want_g);
            let mut got_g = Mat::zeros(3, c);
            simd.forward_gelu_into(&x, &mut got_g);
            assert_eq!(bits(&got_g.data), bits(&want_g.data), "{} gelu {k}x{c}", ops.path);
            let mut want_r = vec![0.0f32; c];
            scalar.forward_row_into(x.row(1), &mut want_r);
            let mut got_r = vec![0.0f32; c];
            simd.forward_row_into(x.row(1), &mut got_r);
            assert_eq!(bits(&got_r), bits(&want_r), "{} row {k}x{c}", ops.path);
        }
    }
}

/// Two-segment ring attention: scores and weighted sums bit-identical
/// at **every** possible segment split (empty-tail, empty-head, and
/// every mid-wrap split) for remainder-heavy and exact-multiple
/// `d_head` widths.
#[test]
fn segment_kernels_are_bitwise_across_paths_at_every_split() {
    let scalar = KernelOps::scalar();
    let rows = 7usize;
    for ops in simd_paths() {
        let mut rng = Rng::new(204);
        for dh in [6usize, 10, 16] {
            let flat = rng.normal_vec(rows * dh, 1.0);
            let q = rng.normal_vec(dh, 1.0);
            for split in 0..=rows {
                let (a, b) = flat.split_at(split * dh);
                let mut want = vec![0.0f32; rows];
                (scalar.dot_scores_segments)(&q, a, b, 0.25, &mut want);
                let mut got = vec![0.0f32; rows];
                (ops.dot_scores_segments)(&q, a, b, 0.25, &mut got);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "{} dot scores dh={dh} split={split}",
                    ops.path
                );
                let mut want_soft = vec![0.0f32; rows];
                (scalar.soft_scores_segments)(&q, a, b, 0.25, &mut want_soft);
                let mut got_soft = vec![0.0f32; rows];
                (ops.soft_scores_segments)(&q, a, b, 0.25, &mut got_soft);
                assert_eq!(
                    bits(&got_soft),
                    bits(&want_soft),
                    "{} soft scores dh={dh} split={split}",
                    ops.path
                );
                let mut want_sum = vec![0.0f32; dh];
                (scalar.weighted_sum_segments)(&want, a, b, &mut want_sum);
                let mut got_sum = vec![0.0f32; dh];
                (ops.weighted_sum_segments)(&want, a, b, &mut got_sum);
                assert_eq!(
                    bits(&got_sum),
                    bits(&want_sum),
                    "{} weighted sum dh={dh} split={split}",
                    ops.path
                );
            }
        }
    }
}

/// RoPE rotation: multi-head rows rotated with memoized table rows,
/// bit-identical across vectorized-pair and remainder-pair widths
/// (`half % 4` ∈ {0, 1, 3}) and several positions. This is the pin
/// that licenses the AVX2 odd-lane operand commutation.
#[test]
fn rope_rotate_is_bitwise_across_paths() {
    let scalar = KernelOps::scalar();
    for ops in simd_paths() {
        let mut rng = Rng::new(205);
        for dh in [2usize, 4, 6, 10, 16, 24] {
            let mut table = RopeTable::new(dh, 1);
            for pos in [0i32, 1, 7, 100] {
                let (sin, cos) = table.row(0, pos);
                let (sin, cos) = (sin.to_vec(), cos.to_vec());
                let row0 = rng.normal_vec(3 * dh, 1.0);
                let mut want = row0.clone();
                (scalar.rope_rotate_row)(&mut want, dh, &sin, &cos);
                let mut got = row0;
                (ops.rope_rotate_row)(&mut got, dh, &sin, &cos);
                assert_eq!(bits(&got), bits(&want), "{} rope dh={dh} pos={pos}", ops.path);
            }
        }
    }
}

/// Fused residual epilogue on both norm modes and both parameter sets
/// (attention / FFN).
#[test]
fn residual_fused_is_bitwise_across_paths() {
    let scalar = KernelOps::scalar();
    for ops in simd_paths() {
        let mut rng = Rng::new(206);
        let (rows, d) = (3usize, 10usize);
        let gain = |rng: &mut Rng| -> Vec<f32> {
            rng.normal_vec(d, 0.2).iter().map(|v| 1.0 + v).collect()
        };
        let norms = [
            (
                "layernorm",
                Norm::LayerNorm {
                    g1: gain(&mut rng),
                    be1: rng.normal_vec(d, 0.1),
                    g2: gain(&mut rng),
                    be2: rng.normal_vec(d, 0.1),
                },
            ),
            ("rezero", Norm::ReZero { a1: 0.7, a2: 0.3 }),
        ];
        for (name, norm) in &norms {
            for idx in [0usize, 1] {
                let x0 = Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0));
                let sub = Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0));
                let mut want = x0.clone();
                residual_fused(scalar, norm, &mut want, &sub, idx);
                let mut got = x0;
                residual_fused(ops, norm, &mut got, &sub, idx);
                assert_eq!(
                    bits(&got.data),
                    bits(&want.data),
                    "{} residual {name} idx={idx}",
                    ops.path
                );
            }
        }
    }
}

/// The engine-level pin: a forced-SIMD batched stepper vs a
/// forced-scalar one over the `tests/kernels_equiv.rs` odd-geometry
/// matrix — remainder `d_head`s, multi-token ticks, both attention
/// modes, both norms, lane counts 1/3/5, and enough ticks that every
/// ring wraps several times. Logits and activations bit-identical at
/// every tick.
#[test]
fn forced_simd_engine_matches_forced_scalar_bitwise() {
    let cases: [(usize, usize, usize, usize, usize, &str, &str); 3] = [
        (12, 2, 2, 7, 1, "softmax", "layernorm"),
        (20, 2, 3, 9, 2, "soft", "rezero"),
        (16, 2, 2, 8, 3, "softmax", "rezero"),
    ];
    for ops in simd_paths() {
        for &(d, h, l, window, m, activation, norm) in &cases {
            let mut cfg = ModelConfig::synthetic(d, h, l, window);
            cfg.m_tokens = m;
            cfg.activation = activation.to_string();
            cfg.norm = norm.to_string();
            let params = ModelParams::synthetic(&cfg, &mut Rng::new(7 + d as u64));
            for lanes in [1usize, 3, 5] {
                let mut scalar = BatchedScalarDeepCoT::with_lanes_ops(
                    cfg.clone(),
                    params.clone(),
                    lanes,
                    KernelOps::scalar(),
                );
                let mut simd =
                    BatchedScalarDeepCoT::with_lanes_ops(cfg.clone(), params.clone(), lanes, ops);
                assert_eq!(simd.dispatch(), ops.path);
                assert_eq!(scalar.dispatch(), DispatchPath::Scalar);
                let mut rng = Rng::new(900 + d as u64);
                for tick in 0..25 {
                    let toks = rng.normal_vec(lanes * m * cfg.d_in, 1.0);
                    let stacked = Mat::from_vec(lanes * m, cfg.d_in, toks);
                    let (want_logits, want_out) = {
                        let s = scalar.tick_all(&stacked).unwrap();
                        (bits(&s.logits.data), bits(&s.out.data))
                    };
                    let (got_logits, got_out) = {
                        let s = simd.tick_all(&stacked).unwrap();
                        (bits(&s.logits.data), bits(&s.out.data))
                    };
                    let label = format!(
                        "{} {d}/{h}/{l} n={window} m={m} {activation}/{norm} lanes={lanes} \
                         tick={tick}",
                        ops.path
                    );
                    assert_eq!(got_logits, want_logits, "{label} logits");
                    assert_eq!(got_out, want_out, "{label} out");
                }
            }
        }
    }
}

/// Migration across dispatch paths: a lane exported from a
/// forced-scalar instance and imported into a forced-SIMD one (the
/// cross-machine migration case where source and target resolved
/// different paths) continues bit-for-bit.
#[test]
fn snapshots_roundtrip_bitwise_across_dispatch_paths() {
    for ops in simd_paths() {
        let mut cfg = ModelConfig::synthetic(16, 2, 2, 6);
        cfg.m_tokens = 2;
        let params = ModelParams::synthetic(&cfg, &mut Rng::new(11));
        let tok_elems = cfg.m_tokens * cfg.d_in;
        let mut scalar = BatchedScalarDeepCoT::with_lanes_ops(
            cfg.clone(),
            params.clone(),
            1,
            KernelOps::scalar(),
        );
        let mut rng = Rng::new(501);
        // 13 ticks of 2 tokens into a 6-slot ring: exported mid-wrap
        for _ in 0..13 {
            let toks = Mat::from_vec(cfg.m_tokens, cfg.d_in, rng.normal_vec(tok_elems, 1.0));
            scalar.tick_all(&toks).unwrap();
        }
        let (mut data, mut heads) = (Vec::new(), Vec::new());
        scalar.export_lane(0, &mut data, &mut heads);
        let mut simd = BatchedScalarDeepCoT::with_lanes_ops(cfg.clone(), params.clone(), 1, ops);
        simd.import_lane(0, &data, &heads).unwrap();
        let mut pos = scalar.lane_pos(0);
        for tick in 0..12 {
            let toks = Mat::from_vec(cfg.m_tokens, cfg.d_in, rng.normal_vec(tok_elems, 1.0));
            let (want_logits, want_out) = {
                let s = scalar.tick_all(&toks).unwrap();
                (bits(&s.logits.data), bits(&s.out.data))
            };
            let (got_logits, got_out) = {
                let s = simd.tick_lanes(&toks, &[true], &[pos]).unwrap();
                (bits(&s.logits.data), bits(&s.out.data))
            };
            assert_eq!(got_logits, want_logits, "{} migrated logits tick {tick}", ops.path);
            assert_eq!(got_out, want_out, "{} migrated out tick {tick}", ops.path);
            pos += cfg.m_tokens as i32;
        }
    }
}
