//! Kernel-suite equivalence: the `nn::kernels` hot path pinned against
//! the frozen `nn::naive` baseline and the old column-major linalg
//! walks, swept across the geometries where the vectorized kernels take
//! their remainder and wraparound paths:
//!
//! * `d_head` not a multiple of the 8-wide unroll (6, 10) — the split
//!   accumulators' remainder lanes;
//! * `mem_len` mid-wraparound — `KvRing::as_segments` returns two
//!   non-empty slices;
//! * single-lane and remainder lane counts (1, 3, 5) — lane-count
//!   invariance of the packed projections;
//! * multi-token ticks (`m_tokens` > 1) and both attention/norm modes.
//!
//! Tolerance policy (see `nn::kernels` docs): the kernel suite uses a
//! fixed summation order that legitimately reassociates f32 sums, so
//! engine-level equivalence vs `nn::naive` is asserted within 1e-4
//! relative tolerance; purely elementwise rewrites (axpy sweeps, the
//! row-sweep Cholesky solve, ridge's outer-product gram build) are
//! asserted **bitwise**.

use deepcot::manifest::ModelConfig;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::kernels;
use deepcot::nn::linalg::{cholesky, cholesky_solve, ridge};
use deepcot::nn::naive::NaiveScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::{self, Mat};
use deepcot::util::rng::Rng;

fn assert_rel_close(what: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol + tol * w.abs(),
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The unrolled reductions match sequential summation for every length
/// through several unroll multiples (full-chunk + remainder paths).
#[test]
fn unrolled_primitives_match_sequential_all_lengths() {
    let mut rng = Rng::new(101);
    for len in 0..=40 {
        let a = rng.normal_vec(len, 1.0);
        let b = rng.normal_vec(len, 1.0);
        let want_dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got_dot = kernels::dot(&a, &b);
        assert!(
            (got_dot - want_dot).abs() <= 1e-4 + 1e-4 * want_dot.abs(),
            "dot len {len}: {got_dot} vs {want_dot}"
        );
        let want_sq = tensor::sqdist(&a, &b);
        let got_sq = kernels::sqdist(&a, &b);
        assert!(
            (got_sq - want_sq).abs() <= 1e-4 + 1e-4 * want_sq.abs(),
            "sqdist len {len}: {got_sq} vs {want_sq}"
        );
    }
}

/// Fixed summation order: the same values produce the same bits no
/// matter where the operands sit in their backing buffers (the order
/// depends on length alone, never on alignment).
#[test]
fn kernel_dot_is_offset_independent() {
    let mut rng = Rng::new(102);
    let len = 37;
    let a = rng.normal_vec(len, 1.0);
    let b = rng.normal_vec(len, 1.0);
    let want = kernels::dot(&a, &b).to_bits();
    for pad in 1..=4 {
        let mut pa = rng.normal_vec(pad, 1.0);
        pa.extend_from_slice(&a);
        let mut pb = rng.normal_vec(pad + 2, 1.0);
        pb.extend_from_slice(&b);
        let got = kernels::dot(&pa[pad..], &pb[pad + 2..]).to_bits();
        assert_eq!(got, want, "dot bits changed at offset {pad}");
    }
}

/// Packed fused matmul+bias vs the naive matmul-then-add_row pipeline,
/// across shapes that exercise full-chunk and remainder dot paths.
#[test]
fn packed_linear_matches_naive_pipeline() {
    let mut rng = Rng::new(103);
    for (k, c) in [(3usize, 5usize), (6, 9), (8, 8), (10, 4), (20, 20), (64, 10)] {
        for rows in [1usize, 2, 5] {
            let w = Mat::from_vec(k, c, rng.normal_vec(k * c, 1.0 / (k as f32).sqrt()));
            let bias = rng.normal_vec(c, 0.1);
            let x = Mat::from_vec(rows, k, rng.normal_vec(rows * k, 1.0));
            let mut want = x.matmul(&w);
            want.add_row(&bias);
            let packed = kernels::PackedLinear::pack(&w, &bias);
            let mut got = Mat::zeros(rows, c);
            packed.forward_into(&x, &mut got);
            assert_rel_close(&format!("packed {rows}x{k}x{c}"), &got.data, &want.data, 1e-4);
            // gelu-fused epilogue vs naive matmul + bias + gelu sweep
            let mut want_g = want.clone();
            for v in want_g.data.iter_mut() {
                *v = tensor::gelu(*v);
            }
            let mut got_g = Mat::zeros(rows, c);
            packed.forward_gelu_into(&x, &mut got_g);
            assert_rel_close(
                &format!("packed gelu {rows}x{k}x{c}"),
                &got_g.data,
                &want_g.data,
                1e-4,
            );
        }
    }
}

/// The engine-level pin: batched kernel-path lanes vs independent
/// frozen-naive steppers, swept over odd geometries, lane counts, both
/// attention modes, both norms, and enough ticks that every ring wraps
/// several times (so `as_segments` serves two non-empty slices at
/// varied splits).
#[test]
fn batched_kernel_path_matches_naive_on_odd_geometries() {
    // (d_model, heads, layers, window, m, activation, norm):
    // dh = 6 and 10 exercise the unroll remainder; m = 2/3 exercise
    // multi-token ticks and mid-window ring offsets
    let cases: [(usize, usize, usize, usize, usize, &str, &str); 3] = [
        (12, 2, 2, 7, 1, "softmax", "layernorm"),
        (20, 2, 3, 9, 2, "soft", "rezero"),
        (16, 2, 2, 8, 3, "softmax", "rezero"),
    ];
    for &(d, h, l, window, m, activation, norm) in &cases {
        let mut cfg = ModelConfig::synthetic(d, h, l, window);
        cfg.m_tokens = m;
        cfg.activation = activation.to_string();
        cfg.norm = norm.to_string();
        let params = ModelParams::synthetic(&cfg, &mut Rng::new(7 + d as u64));
        for lanes in [1usize, 3, 5] {
            let mut batched = BatchedScalarDeepCoT::with_lanes(cfg.clone(), params.clone(), lanes);
            let mut naives: Vec<NaiveScalarDeepCoT> = (0..lanes)
                .map(|_| NaiveScalarDeepCoT::new(cfg.clone(), params.clone()))
                .collect();
            let mut rngs: Vec<Rng> = (0..lanes).map(|s| Rng::new(900 + s as u64)).collect();
            for tick in 0..25 {
                let mut stacked = Mat::zeros(lanes * m, cfg.d_in);
                let mut lane_toks = Vec::new();
                for (s, rng) in rngs.iter_mut().enumerate() {
                    let toks = rng.normal_vec(m * cfg.d_in, 1.0);
                    stacked.data[s * m * cfg.d_in..(s + 1) * m * cfg.d_in]
                        .copy_from_slice(&toks);
                    lane_toks.push(toks);
                }
                let mut want: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                for (solo, toks) in naives.iter_mut().zip(&lane_toks) {
                    let t = Mat::from_vec(m, cfg.d_in, toks.clone());
                    let (lg, out) = solo.tick(&t).unwrap();
                    want.push((lg, out.data));
                }
                let step = batched.tick_all(&stacked).unwrap();
                for s in 0..lanes {
                    let label =
                        format!("{d}/{h}/{l} n={window} m={m} {activation}/{norm} lanes={lanes} \
                         tick={tick} lane={s}");
                    assert_rel_close(
                        &format!("{label} logits"),
                        step.logits.row(s),
                        &want[s].0,
                        1e-4,
                    );
                    let got_out = step.out.rows_view(s * m, m);
                    assert_rel_close(
                        &format!("{label} out"),
                        got_out.as_slice(),
                        &want[s].1,
                        1e-4,
                    );
                }
            }
        }
    }
}

/// Lane-count invariance, bitwise: the same stream stepped in a 1-lane
/// and a 5-lane instance (other lanes busy) produces identical bits —
/// the property the sharded cluster's layout-equivalence rests on.
#[test]
fn lane_count_never_changes_a_streams_bits() {
    let cfg = ModelConfig::synthetic(20, 2, 2, 9);
    let params = ModelParams::synthetic(&cfg, &mut Rng::new(23));
    let mut solo = BatchedScalarDeepCoT::with_lanes(cfg.clone(), params.clone(), 1);
    let mut wide = BatchedScalarDeepCoT::with_lanes(cfg.clone(), params, 5);
    let mut stream_rng = Rng::new(31);
    let mut noise_rng = Rng::new(37);
    let mut pos = 0i32;
    for _ in 0..20 {
        let tok = stream_rng.normal_vec(cfg.d_in, 1.0);
        let solo_toks = Mat::from_vec(1, cfg.d_in, tok.clone());
        let mut wide_toks = Mat::from_vec(5, cfg.d_in, noise_rng.normal_vec(5 * cfg.d_in, 1.0));
        wide_toks.row_mut(2).copy_from_slice(&tok);
        let (sl, so) = {
            let s = solo.tick_lanes(&solo_toks, &[true], &[pos]).unwrap();
            (s.logits.row(0).to_vec(), s.out.row(0).to_vec())
        };
        let (wl, wo) = {
            let live = [true, true, true, true, true];
            let p = [pos + 7, pos + 1, pos, pos + 3, pos];
            let s = wide.tick_lanes(&wide_toks, &live, &p).unwrap();
            (s.logits.row(2).to_vec(), s.out.row(2).to_vec())
        };
        assert_eq!(bits(&sl), bits(&wl), "logits bits diverged across lane counts");
        assert_eq!(bits(&so), bits(&wo), "activation bits diverged across lane counts");
        pos += 1;
    }
}

/// Old column-major forward/backward substitution, kept verbatim as the
/// reference the cache-friendly row sweep must reproduce bitwise.
fn cholesky_solve_column_walk(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    let mut x = b.clone();
    for col in 0..b.cols {
        for i in 0..n {
            let mut s = x.at(i, col);
            for k in 0..i {
                s -= l.at(i, k) * x.at(k, col);
            }
            *x.at_mut(i, col) = s / l.at(i, i);
        }
        for i in (0..n).rev() {
            let mut s = x.at(i, col);
            for k in i + 1..n {
                s -= l.at(k, i) * x.at(k, col);
            }
            *x.at_mut(i, col) = s / l.at(i, i);
        }
    }
    x
}

#[test]
fn row_sweep_cholesky_solve_is_bitwise_identical_to_column_walk() {
    let mut rng = Rng::new(104);
    for (n, cols) in [(1usize, 1usize), (4, 3), (9, 7), (16, 5)] {
        // SPD via A = G G^T + n·I
        let g = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let mut a = g.matmul(&g.transpose());
        for i in 0..n {
            *a.at_mut(i, i) += n as f32;
        }
        let l = cholesky(&a).unwrap();
        let b = Mat::from_vec(n, cols, rng.normal_vec(n * cols, 1.0));
        let got = cholesky_solve(&l, &b);
        let want = cholesky_solve_column_walk(&l, &b);
        assert_eq!(bits(&got.data), bits(&want.data), "solve bits diverged at n={n}");
    }
}

/// Ridge's outer-product gram build vs the old transpose+matmul
/// formulation — same inner-dimension summation order, bitwise equal.
#[test]
fn ridge_outer_product_build_is_bitwise_identical_to_matmul_build() {
    let mut rng = Rng::new(105);
    let (n, d, c) = (60usize, 11usize, 3usize);
    let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
    let y = Mat::from_vec(n, c, rng.normal_vec(n * c, 1.0));
    let lambda = 1e-2;
    let got = ridge(&x, &y, lambda).unwrap();
    // old formulation, verbatim
    let xt = x.transpose();
    let mut gram = xt.matmul(&x);
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += lambda;
    }
    let l = cholesky(&gram).unwrap();
    let xty = xt.matmul(&y);
    let want = cholesky_solve_column_walk(&l, &xty);
    assert_eq!(bits(&got.data), bits(&want.data), "ridge bits diverged");
}
