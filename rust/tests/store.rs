//! Property + fuzz tests for the persistence layer (`store::codec`,
//! `store::disk`): random records round-trip bit-exactly (NaN payloads,
//! infinities, signed zeros included), and corrupted input — truncated,
//! bitflipped, or pure byte soup — always comes back as a typed
//! `StoreError`, never a panic and never a huge speculative allocation.
//! This is the contract hibernation and crash recovery stand on: the
//! state file is the one input the server reads that a crash can
//! mangle arbitrarily.

use std::path::PathBuf;

use deepcot::store::codec::{crc32, StreamRecord, MIN_LEN};
use deepcot::store::disk::DiskStore;
use deepcot::store::{MemStore, StateStore, StoreError};
use deepcot::util::prop;
use deepcot::util::rng::Rng;

fn tmp_path(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("deepcot-store-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Random record with arbitrary f32 bit patterns — NaNs, infinities,
/// denormals and signed zeros all occur.
fn rand_record(rng: &mut Rng) -> StreamRecord {
    let n_heads = rng.below(8);
    let n_kv = rng.below(64);
    let n_queued = rng.below(4);
    StreamRecord {
        stream: rng.next_u64(),
        ticks: rng.next_u64() >> 16,
        pos: rng.next_u64() as u32 as i32,
        write_heads: (0..n_heads).map(|_| rng.below(1 << 20)).collect(),
        kv_rings: (0..n_kv).map(|_| f32::from_bits(rng.next_u64() as u32)).collect(),
        queued: (0..n_queued)
            .map(|_| (0..rng.below(6)).map(|_| f32::from_bits(rng.next_u64() as u32)).collect())
            .collect(),
    }
}

/// Bit-level equality (PartialEq would fail on NaN payloads).
fn bits_eq(a: &StreamRecord, b: &StreamRecord) -> Result<(), String> {
    if a.stream != b.stream || a.ticks != b.ticks || a.pos != b.pos {
        return Err(format!("header fields diverged: {a:?} vs {b:?}"));
    }
    if a.write_heads != b.write_heads {
        return Err("write heads diverged".into());
    }
    let kv_a: Vec<u32> = a.kv_rings.iter().map(|v| v.to_bits()).collect();
    let kv_b: Vec<u32> = b.kv_rings.iter().map(|v| v.to_bits()).collect();
    if kv_a != kv_b {
        return Err("kv rings diverged bitwise".into());
    }
    if a.queued.len() != b.queued.len() {
        return Err("queued counts diverged".into());
    }
    for (qa, qb) in a.queued.iter().zip(&b.queued) {
        let ba: Vec<u32> = qa.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = qb.iter().map(|v| v.to_bits()).collect();
        if ba != bb {
            return Err("queued tokens diverged bitwise".into());
        }
    }
    Ok(())
}

#[test]
fn prop_records_round_trip_bit_exact() {
    prop::check("store-roundtrip", 300, |rng| {
        let rec = rand_record(rng);
        let blob = rec.encode();
        if blob.len() != rec.encoded_len() {
            return Err(format!("encoded {} bytes, encoded_len says {}", blob.len(), rec.encoded_len()));
        }
        let back = StreamRecord::decode(&blob).map_err(|e| format!("decode failed: {e}"))?;
        bits_eq(&rec, &back)?;
        // encode_into through a dirty reused buffer must be byte-identical
        let mut buf = vec![0x5A; 13];
        rec.encode_into(&mut buf);
        if buf != blob {
            return Err("encode_into(reused buffer) diverged from encode()".into());
        }
        // decode_into reusing a previously-populated record too
        let mut target = rand_record(rng);
        target.decode_into(&blob).map_err(|e| format!("decode_into failed: {e}"))?;
        bits_eq(&rec, &target)
    });
}

#[test]
fn prop_truncations_always_typed_errors() {
    prop::check("store-truncation", 60, |rng| {
        let blob = rand_record(rng).encode();
        for cut in 0..blob.len() {
            match StreamRecord::decode(&blob[..cut]) {
                Ok(_) => return Err(format!("{cut}-byte prefix of a {}-byte record decoded Ok", blob.len())),
                Err(StoreError::Corrupt(_)) => {}
                Err(e) => return Err(format!("truncation surfaced non-corrupt error: {e}")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitflips_always_detected() {
    prop::check("store-bitflip", 120, |rng| {
        let blob = rand_record(rng).encode();
        let byte = rng.below(blob.len());
        let mut bad = blob.clone();
        bad[byte] ^= 1 << rng.below(8);
        match StreamRecord::decode(&bad) {
            Ok(_) => Err(format!("bitflip at byte {byte} went undetected")),
            Err(StoreError::Corrupt(_)) => Ok(()),
            Err(e) => Err(format!("bitflip surfaced non-corrupt error: {e}")),
        }
    });
}

/// ≥10k corrupted blobs pushed through the *disk* store and decoded:
/// the store hands back whatever bytes were stored (blobs are opaque
/// to it), and the codec must reject every one with a typed error —
/// never a panic, even for adversarial count fields resealed with a
/// valid CRC.
#[test]
fn fuzz_10k_corrupted_blobs_through_disk_store() {
    let path = tmp_path("fuzz");
    let mut store = DiskStore::open(&path).expect("open fuzz store");
    let mut rng = Rng::new(0xF0DD);
    let mut rejected = 0u32;
    for i in 0..10_000u64 {
        let rec = rand_record(&mut rng);
        let mut blob = rec.encode();
        match rng.below(4) {
            // truncate somewhere (possibly below MIN_LEN)
            0 => blob.truncate(rng.below(blob.len())),
            // flip 1..=8 random bits
            1 => {
                for _ in 0..rng.below(8) + 1 {
                    let at = rng.below(blob.len());
                    blob[at] ^= 1 << rng.below(8);
                }
            }
            // pure byte soup
            2 => blob = (0..rng.below(200)).map(|_| rng.next_u64() as u8).collect(),
            // adversarial: corrupt a count field, then reseal the CRC so
            // only bounds checking can catch it
            _ => {
                if blob.len() >= MIN_LEN {
                    let off = 32 + 4 * rng.below(3); // n_heads / n_kv / n_queued
                    blob[off..off + 4].copy_from_slice(&(u32::MAX - 7).to_le_bytes());
                    let body = blob.len() - 4;
                    let crc = crc32(&blob[..body]);
                    blob[body..].copy_from_slice(&crc.to_le_bytes());
                }
            }
        }
        store.put(i, &blob).expect("store accepts opaque bytes");
        let back = store.get(i).expect("get").expect("just stored");
        assert_eq!(back, blob, "disk store must hand bytes back verbatim");
        match StreamRecord::decode(&back) {
            Err(StoreError::Corrupt(_)) => rejected += 1,
            Err(e) => panic!("corrupt blob {i} surfaced non-corrupt error: {e}"),
            // a lucky no-op corruption (e.g. zero bitflips selected) can
            // only happen for case 1 with an unchanged byte — impossible
            // here since every flip changes exactly one bit; case 2 soup
            // passing CRC+magic is ~2^-64. Treat Ok as a real failure.
            Ok(_) => panic!("corrupt blob {i} decoded Ok"),
        }
        // keep the log from growing without bound; deletes also feed
        // the compaction path with garbage entries
        store.delete(i).expect("delete");
    }
    assert_eq!(rejected, 10_000);
    let _ = std::fs::remove_file(&path);
}

/// Random bytes stomped over the middle of a real log file: reopen must
/// recover cleanly (valid prefix) or fail typed — never panic.
#[test]
fn fuzz_corrupted_log_files_never_panic_on_reopen() {
    let mut rng = Rng::new(0xD15C);
    for case in 0..150 {
        let path = tmp_path(&format!("logfuzz-{case}"));
        {
            let mut s = DiskStore::open(&path).expect("open");
            for id in 0..6u64 {
                s.put(id, &rand_record(&mut rng).encode()).expect("put");
            }
        }
        let mut bytes = std::fs::read(&path).expect("read log");
        for _ in 0..rng.below(12) + 1 {
            let at = rng.below(bytes.len());
            bytes[at] = rng.next_u64() as u8;
        }
        std::fs::write(&path, &bytes).expect("write corrupted log");
        // contract: typed result either way, and a store that does open
        // keeps serving gets/puts without panicking
        if let Ok(mut s) = DiskStore::open(&path) {
            for id in s.list().expect("list") {
                let _ = s.get(id);
            }
            s.put(99, b"still writable").expect("post-recovery put");
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// The same random op sequence applied to `MemStore` and `DiskStore`
/// (with periodic reopens) must be observationally identical.
#[test]
fn prop_disk_store_matches_memstore_model() {
    let path = tmp_path("model");
    let mut disk = DiskStore::open(&path).expect("open");
    let mut mem = MemStore::new();
    let mut rng = Rng::new(0x10DE1);
    for step in 0..2_000 {
        let id = rng.below(24) as u64;
        match rng.below(5) {
            0 | 1 => {
                let blob: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
                disk.put(id, &blob).expect("disk put");
                mem.put(id, &blob).expect("mem put");
            }
            2 => {
                assert_eq!(disk.get(id).expect("disk get"), mem.get(id).expect("mem get"), "step {step}");
            }
            3 => {
                assert_eq!(disk.delete(id).expect("disk del"), mem.delete(id).expect("mem del"), "step {step}");
            }
            _ => {
                assert_eq!(disk.list().expect("disk list"), mem.list().expect("mem list"), "step {step}");
            }
        }
        if step % 500 == 499 {
            // survive a reopen (and whatever compactions happened)
            drop(disk);
            disk = DiskStore::open(&path).expect("reopen");
            assert_eq!(disk.list().expect("list"), mem.list().expect("list"));
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Compaction drops dead bytes but never live records.
#[test]
fn compaction_preserves_live_records() {
    let path = tmp_path("compact");
    let mut s = DiskStore::open(&path).expect("open");
    let mut rng = Rng::new(0xC0);
    let keep: Vec<(u64, Vec<u8>)> = (0..8u64)
        .map(|id| (id, rand_record(&mut rng).encode()))
        .collect();
    for (id, blob) in &keep {
        s.put(*id, blob).expect("put");
    }
    // churn overwrites to build up dead bytes
    for _ in 0..200 {
        let id = 100 + rng.below(4) as u64;
        s.put(id, &rand_record(&mut rng).encode()).expect("churn put");
    }
    for id in 100..104u64 {
        let _ = s.delete(id);
    }
    let (live_before, _) = s.byte_usage();
    s.compact().expect("compact");
    let (live_after, dead_after) = s.byte_usage();
    assert_eq!(dead_after, 0, "compaction must leave no dead bytes");
    assert_eq!(live_before, live_after, "compaction must not change live bytes");
    for (id, blob) in &keep {
        assert_eq!(s.get(*id).expect("get").as_deref(), Some(blob.as_slice()));
    }
    // and the compacted file still reopens to the same contents
    drop(s);
    let mut s = DiskStore::open(&path).expect("reopen");
    assert_eq!(s.list().expect("list").len(), keep.len());
    let _ = std::fs::remove_file(&path);
}
