//! Hibernation integration: spill/restore and crash recovery must be
//! **bitwise-invisible** to streams.
//!
//! The subsystem's acceptance properties, pinned end to end:
//!
//! 1. A stream served on a slot-starved cluster (every push first has
//!    to wake it from the state store, spilling a warmer victim) emits
//!    `TickResult`s bitwise-identical to the same trace on a cluster
//!    with lanes to spare — steady traffic and open/close churn both.
//! 2. A 64-lane cluster serves 10 000 registered streams under random
//!    wake patterns, every output bitwise equal to a per-stream scalar
//!    oracle replay.
//! 3. Snapshot → kill (sessions never close) → recover on a fresh
//!    engine restores every registered stream's state bit-exactly:
//!    `resume(id)` continues the tick series as if the crash never
//!    happened.
//!
//! Hermetic: `SyntheticServeSpec::default()` artifacts on the batched
//! scalar backend, serial drivers, deterministic seeds throughout.

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::{EngineError, EngineThread, Session, TickResult};
use deepcot::coordinator::slots::StreamId;
use deepcot::manifest::Manifest;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::Mat;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn base_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .build()
}

fn hib_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .hibernate(true)
        .build()
}

fn recv_tick(sess: &Session) -> TickResult {
    sess.recv_timeout(Duration::from_secs(30)).expect("tick result")
}

fn assert_bitwise(label: &str, a: &[Vec<TickResult>], b: &[Vec<TickResult>]) {
    assert_eq!(a.len(), b.len(), "{label}: stream count");
    for (s, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{label}: stream {s} tick count");
        for (t, (ra, rb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(ra.tick, rb.tick, "{label}: stream {s} tick {t} ordinal");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&ra.logits), bits(&rb.logits), "{label}: stream {s} tick {t} logits");
            assert_eq!(bits(&ra.out), bits(&rb.out), "{label}: stream {s} tick {t} out");
        }
    }
}

/// Steady serial trace: STREAMS streams, TICKS rounds, every stream
/// ticks every round.
fn run_steady_trace(cfg: EngineConfig) -> Vec<Vec<TickResult>> {
    const STREAMS: usize = 6;
    const TICKS: usize = 8;
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let mut sessions = Vec::new();
    for s in 0..STREAMS {
        sessions.push((h.open().unwrap(), Rng::new(7100 + s as u64)));
    }
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); STREAMS];
    for _round in 0..TICKS {
        for (s, (sess, rng)) in sessions.iter_mut().enumerate() {
            sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
            traces[s].push(recv_tick(sess));
        }
    }
    for (sess, _) in sessions {
        sess.close();
    }
    engine.shutdown().unwrap();
    traces
}

/// Bitwise transparency under steady traffic: 6 streams on 4 lanes —
/// every round-robin push wakes a hibernated stream and spills another
/// (constant churn through the store) — versus 6 streams with lanes to
/// spare and no hibernation at all.
#[test]
fn hibernation_is_bitwise_invisible_steady() {
    let roomy = run_steady_trace(base_cfg(2, 6));
    let starved = run_steady_trace(hib_cfg(2, 2));
    assert_bitwise("steady: starved+hibernating vs roomy", &roomy, &starved);
    // the single-lane extreme: every push of every stream goes through
    // a full spill/restore cycle
    let single_lane = run_steady_trace(hib_cfg(1, 1));
    assert_bitwise("steady: 1 lane vs roomy", &roomy, &single_lane);
}

/// Open/close churn variant: streams open mid-run, close, recycle
/// capacity — with hibernation multiplexing 1-2 lanes under them.
fn run_churn_trace(cfg: EngineConfig) -> Vec<Vec<TickResult>> {
    const LOGICAL: usize = 6;
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let mut sessions: Vec<Option<Session>> = (0..LOGICAL).map(|_| None).collect();
    let mut rngs: Vec<Rng> = (0..LOGICAL).map(|s| Rng::new(8200 + s as u64)).collect();
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); LOGICAL];
    for sess in sessions.iter_mut().take(4) {
        *sess = Some(h.open().unwrap());
    }
    for round in 0..12 {
        if round == 4 {
            for s in [1, 3] {
                sessions[s].take().unwrap().close();
            }
            sessions[4] = Some(h.open().unwrap());
        }
        if round == 8 {
            sessions[0].take().unwrap().close();
            sessions[5] = Some(h.open().unwrap());
        }
        for ((sess, rng), trace) in sessions.iter().zip(rngs.iter_mut()).zip(traces.iter_mut()) {
            if let Some(sess) = sess {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                trace.push(recv_tick(sess));
            }
        }
    }
    for sess in sessions.into_iter().flatten() {
        sess.close();
    }
    engine.shutdown().unwrap();
    traces
}

#[test]
fn hibernation_is_bitwise_invisible_under_churn() {
    let roomy = run_churn_trace(base_cfg(1, 4));
    let starved = run_churn_trace(hib_cfg(2, 1));
    assert_bitwise("churn: starved+hibernating vs roomy", &roomy, &starved);
}

/// Slot capacity bounds *active* streams, not registered ones: a
/// 64-lane cluster carries 10 000 registered streams, woken in a
/// seeded random pattern, and every output matches a per-stream scalar
/// oracle replay bit for bit. (The oracle check runs as a replay at
/// the end so the test never holds 10k oracle instances at once.)
#[test]
fn ten_thousand_registered_streams_on_64_lanes_match_oracles() {
    const REGISTERED: usize = 10_000;
    const WAKES: usize = 3_000;
    let seed_of = |s: usize| 0x5EED_0000 + s as u64;

    let engine = EngineThread::spawn(hib_cfg(4, 16)).unwrap(); // 64 lanes
    let h = engine.handle();
    let mut sessions = Vec::with_capacity(REGISTERED);
    for s in 0..REGISTERED {
        sessions.push((h.open().unwrap(), Rng::new(seed_of(s))));
    }
    // far more registered than lanes: almost everything is hibernated
    let m = h.metrics().unwrap();
    assert_eq!(m.streams_opened, REGISTERED as u64);
    assert!(
        m.hibernated_resident >= (REGISTERED - 64) as u64,
        "only 64 lanes exist, got {} hibernated",
        m.hibernated_resident
    );

    // random wakes; record output bits per stream for the replay below
    let mut outputs: Vec<Vec<(u64, Vec<u32>, Vec<u32>)>> = vec![Vec::new(); REGISTERED];
    let mut pick = Rng::new(0xA11_CE);
    for _ in 0..WAKES {
        let s = pick.below(REGISTERED);
        let (sess, rng) = &mut sessions[s];
        sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
        let got = recv_tick(sess);
        assert_eq!(got.tick, outputs[s].len() as u64 + 1, "stream {s} tick ordinal");
        outputs[s].push((
            got.tick,
            got.logits.iter().map(|v| v.to_bits()).collect(),
            got.out.iter().map(|v| v.to_bits()).collect(),
        ));
    }
    let m = h.metrics().unwrap();
    assert!(m.streams_hibernated > 0, "wake churn must have spilled streams");
    assert!(m.streams_restored > 0, "wake churn must have restored streams");

    drop(sessions); // 10k closes
    engine.shutdown().unwrap();

    // oracle replay: one isolated 1-lane scalar model per woken stream,
    // fed the same deterministic token sequence
    let (manifest, mdir) = Manifest::load(&synth_artifacts()).unwrap();
    let entry = manifest.variant(&SyntheticServeSpec::variant_name(1)).unwrap();
    let params = ModelParams::load(&mdir, entry).unwrap();
    let mc = entry.config.clone();
    let mut checked = 0usize;
    for (s, ticks) in outputs.iter().enumerate() {
        if ticks.is_empty() {
            continue;
        }
        let mut oracle = BatchedScalarDeepCoT::with_lanes(mc.clone(), params.clone(), 1);
        let mut rng = Rng::new(seed_of(s));
        for (t, (ord, logits_bits, out_bits)) in ticks.iter().enumerate() {
            let toks = rng.normal_vec(mc.d_in, 1.0);
            let tokens = Mat::from_vec(1, mc.d_in, toks);
            let step = oracle.tick_lanes(&tokens, &[true], &[t as i32]).unwrap();
            assert_eq!(*ord, t as u64 + 1);
            let want_logits: Vec<u32> = step.logits.row(0).iter().map(|v| v.to_bits()).collect();
            let want_out: Vec<u32> = (0..mc.m_tokens)
                .flat_map(|r| step.out.row(r).iter().map(|v| v.to_bits()))
                .collect();
            assert_eq!(logits_bits, &want_logits, "stream {s} tick {t} logits vs oracle");
            assert_eq!(out_bits, &want_out, "stream {s} tick {t} out vs oracle");
        }
        checked += 1;
    }
    assert!(checked > 1_000, "wake pattern under-covered: only {checked} streams woke");
}

fn tmp_state_dir(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("deepcot-hibernate-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// Crash → recover, bit-exact: run streams on a disk-backed engine,
/// snapshot, then *kill* it (sessions are forgotten, never closed — a
/// close would legitimately delete the stored state). A fresh engine
/// over the same state dir must recover every stream as hibernated and
/// `resume` must continue each one such that the concatenated trace is
/// bitwise-identical to an uninterrupted run.
#[test]
fn crash_recovery_restores_every_stream_bit_exactly() {
    const STREAMS: usize = 5;
    const TICKS_BEFORE: usize = 4;
    const TICKS_AFTER: usize = 4;
    let seed_of = |s: usize| 9300 + s as u64;

    // the uninterrupted reference: same seeds, one engine, full trace
    let mut reference: Vec<Vec<TickResult>> = vec![Vec::new(); STREAMS];
    {
        let engine = EngineThread::spawn(base_cfg(2, 4)).unwrap();
        let h = engine.handle();
        let mut sessions: Vec<(Session, Rng)> =
            (0..STREAMS).map(|s| (h.open().unwrap(), Rng::new(seed_of(s)))).collect();
        for _ in 0..TICKS_BEFORE + TICKS_AFTER {
            for (s, (sess, rng)) in sessions.iter_mut().enumerate() {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                reference[s].push(recv_tick(sess));
            }
        }
        for (sess, _) in sessions {
            sess.close();
        }
        engine.shutdown().unwrap();
    }

    let dir = tmp_state_dir("crash");
    let mut ids: Vec<StreamId> = Vec::new();
    let mut crash_trace: Vec<Vec<TickResult>> = vec![Vec::new(); STREAMS];
    // phase 1: serve, snapshot, crash
    {
        let cfg = EngineConfig::builder()
            .variant(SyntheticServeSpec::variant_name(1))
            .artifacts_dir(synth_artifacts())
            .backend(EngineBackend::Scalar)
            .batch_deadline(Duration::from_millis(1))
            .shards(2)
            .slots_per_shard(4)
            .state_dir(dir.clone())
            .build();
        let engine = EngineThread::spawn(cfg).unwrap();
        let h = engine.handle();
        let mut sessions: Vec<(Session, Rng)> =
            (0..STREAMS).map(|s| (h.open().unwrap(), Rng::new(seed_of(s)))).collect();
        for (sess, _) in &sessions {
            ids.push(sess.id());
        }
        for _ in 0..TICKS_BEFORE {
            for (s, (sess, rng)) in sessions.iter_mut().enumerate() {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                crash_trace[s].push(recv_tick(sess));
            }
        }
        let n = h.snapshot().unwrap();
        assert_eq!(n, STREAMS, "snapshot must checkpoint every lane-resident stream");
        assert!(dir.join("streams.log").exists(), "state dir must hold the log");
        // the crash: owners vanish without closing (a close would
        // rightly delete the stored blobs), then the engine dies
        for (sess, _) in sessions {
            std::mem::forget(sess);
        }
        engine.shutdown().unwrap();
    }

    // phase 2: recover on a fresh engine over the same state dir
    {
        let cfg = EngineConfig::builder()
            .variant(SyntheticServeSpec::variant_name(1))
            .artifacts_dir(synth_artifacts())
            .backend(EngineBackend::Scalar)
            .batch_deadline(Duration::from_millis(1))
            .shards(2)
            .slots_per_shard(4)
            .state_dir(dir.clone())
            .build();
        let engine = EngineThread::spawn(cfg).unwrap();
        let h = engine.handle();
        let m = h.metrics().unwrap();
        assert_eq!(m.streams_recovered, STREAMS as u64, "recover-on-boot must see every stream");
        let mut recovered = h.hibernated_streams();
        recovered.sort_by_key(|id| id.0);
        let mut want = ids.clone();
        want.sort_by_key(|id| id.0);
        assert_eq!(recovered, want, "every registered stream recovers as hibernated");
        for id in &ids {
            assert!(h.is_hibernated(*id));
        }

        // new opens must not collide with recovered ids
        let fresh = h.open().unwrap();
        assert!(!ids.contains(&fresh.id()), "recovered ids must stay reserved");
        fresh.close();

        let mut sessions: Vec<(Session, Rng)> = ids
            .iter()
            .enumerate()
            .map(|(s, id)| {
                let sess = h.resume(*id).expect("resume recovered stream");
                assert_eq!(sess.id(), *id);
                let mut rng = Rng::new(seed_of(s));
                // replay the pre-crash draws so the token stream continues
                for _ in 0..TICKS_BEFORE {
                    let _ = rng.normal_vec(D_IN, 1.0);
                }
                (sess, rng)
            })
            .collect();
        // double-resume of a now-live stream must be refused, typed
        let err = h.resume(ids[0]).expect_err("resume of a live stream must fail");
        assert!(matches!(err, EngineError::InvalidRequest(_)), "got {err:?}");
        for _ in 0..TICKS_AFTER {
            for (s, (sess, rng)) in sessions.iter_mut().enumerate() {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                crash_trace[s].push(recv_tick(sess));
            }
        }
        for (sess, _) in sessions {
            sess.close();
        }
        engine.shutdown().unwrap();
    }
    assert_bitwise("crash-recover vs uninterrupted", &reference, &crash_trace);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Typed-error semantics around hibernate/resume, plus the journal
/// trail and counters.
#[test]
fn resume_and_hibernate_error_semantics() {
    use deepcot::obs::journal::EventKind;
    use deepcot::obs::ObsLevel;
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(1)
        .slots_per_shard(1)
        .hibernate(true)
        .obs(ObsLevel::Journal)
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let mut rng = Rng::new(44);

    let a = h.open().unwrap();
    a.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    recv_tick(&a);
    // one lane: opening B spills A
    let b = h.open().unwrap();
    assert!(h.is_hibernated(a.id()), "A must hibernate when B takes the only lane");
    assert!(!h.is_hibernated(b.id()));
    assert_eq!(h.hibernated_streams(), vec![a.id()]);

    // a hibernated stream with a live owner wakes on push, not resume
    let err = h.resume(a.id()).expect_err("resume with live owner must fail");
    assert!(matches!(err, EngineError::InvalidRequest(_)), "got {err:?}");
    a.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    let out = recv_tick(&a);
    assert_eq!(out.tick, 2, "wake must continue the tick series");
    assert!(h.is_hibernated(b.id()), "waking A must spill B in turn");

    // unknown streams are StreamClosed, exactly as before hibernation
    let err = h.resume(StreamId(999_999)).expect_err("unknown id");
    assert!(matches!(err, EngineError::StreamClosed(_)), "got {err:?}");
    // resuming a live (lane-resident) stream is refused, typed
    let err = h.resume(a.id()).expect_err("resume of live stream");
    assert!(matches!(err, EngineError::InvalidRequest(_)), "got {err:?}");

    // snapshot without a state dir still checkpoints into the mem store
    let n = h.snapshot().unwrap();
    assert_eq!(n, 1, "one lane-resident stream to checkpoint");

    let m = h.metrics().unwrap();
    assert!(m.streams_hibernated >= 2, "got {}", m.streams_hibernated);
    assert!(m.streams_restored >= 1, "got {}", m.streams_restored);
    assert_eq!(m.hibernated_resident, 1);
    assert_eq!(m.snapshots_taken, 1);
    assert_eq!(m.snapshot_latency.count(), 1);

    let events = h.obs().journal().drain();
    let has = |k: EventKind| events.iter().any(|e| e.kind == k);
    assert!(has(EventKind::StreamHibernate), "spill must journal StreamHibernate");
    assert!(has(EventKind::StreamRestore), "wake must journal StreamRestore");
    assert!(has(EventKind::Snapshot), "snapshot must journal Snapshot");

    // closing a hibernated stream forgets it entirely
    let b_id = b.id();
    b.close();
    for _ in 0..50 {
        if !h.is_hibernated(b_id) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(!h.is_hibernated(b_id), "close must forget a hibernated stream");
    let err = h.resume(b_id).expect_err("closed stream cannot resume");
    assert!(matches!(err, EngineError::StreamClosed(_)), "got {err:?}");

    a.close();
    engine.shutdown().unwrap();
}

/// Without hibernation configured, resume is a typed configuration
/// error and capacity semantics are exactly the legacy ones.
#[test]
fn resume_without_hibernation_is_a_typed_config_error() {
    let engine = EngineThread::spawn(base_cfg(1, 1)).unwrap();
    let h = engine.handle();
    let a = h.open().unwrap();
    // legacy semantics intact: a full cluster rejects instead of spilling
    let err = h.open().expect_err("second open must saturate a 1x1 cluster");
    assert!(matches!(err, EngineError::Saturated { .. }), "got {err:?}");
    let err = h.resume(a.id()).expect_err("resume without hibernation");
    assert!(matches!(err, EngineError::InvalidRequest(_)), "got {err:?}");
    assert!(!h.is_hibernated(a.id()));
    assert!(h.hibernated_streams().is_empty());
    assert_eq!(h.snapshot().unwrap(), 0, "snapshot is a no-op without a pool");
    a.close();
    engine.shutdown().unwrap();
}
