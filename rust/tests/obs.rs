//! Observability-layer integration tests: stage spans must *partition*
//! the tick pipeline (the four engine segments sum to `pipeline_total`
//! within timer truncation), `obs=off` must record nothing new, the
//! journal must capture lifecycle events in order, the HTTP metrics
//! endpoint and the `METRICS_PROM` wire frame must serve well-formed
//! expositions over a live engine, every per-shard Prometheus series
//! must sum back to its cluster aggregate, and the histogram/journal
//! primitives must hold their invariants under random inputs
//! (`util::prop`).
//!
//! Hermetic: `SyntheticServeSpec::default()` artifacts on the scalar
//! backend, ephemeral loopback ports, bounded timeouts.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::{EngineHandle, EngineThread};
use deepcot::coordinator::metrics::LatencyHisto;
use deepcot::net::client::NetClient;
use deepcot::net::server::NetServer;
use deepcot::obs::expo;
use deepcot::obs::journal::{EventKind, Journal};
use deepcot::obs::server::{MetricsFormat, MetricsServer};
use deepcot::obs::span::Stage;
use deepcot::obs::ObsLevel;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::json::Json;
use deepcot::util::prop;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn cluster_cfg(shards: usize, slots_per_shard: usize, obs: ObsLevel) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .obs(obs)
        .build()
}

/// Serial closed-loop traffic: `streams` sessions, `rounds` ticks each.
fn drive(h: &EngineHandle, streams: usize, rounds: usize) {
    let sessions: Vec<_> = (0..streams).map(|_| h.open().expect("open")).collect();
    let mut rng = Rng::new(0x0B5E);
    for _ in 0..rounds {
        for sess in &sessions {
            sess.push(rng.normal_vec(D_IN, 1.0)).expect("push");
            sess.recv_timeout(Duration::from_secs(30)).expect("tick result");
        }
    }
    for sess in sessions {
        sess.close();
    }
}

/// Raw `GET path`; returns the full response (status line + body).
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut sock = TcpStream::connect(addr).expect("connect metrics endpoint");
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(sock, "GET {path} HTTP/1.0\r\n\r\n").unwrap();
    let mut out = String::new();
    sock.read_to_string(&mut out).expect("read scrape");
    out
}

fn body_of(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("")
}

/// Value of an unlabelled Prometheus sample line (`name value`).
fn prom_value(body: &str, name: &str) -> f64 {
    body.lines()
        .find_map(|l| {
            let rest = l.strip_prefix(name)?;
            if !rest.starts_with(' ') {
                return None;
            }
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or_else(|| panic!("no sample {name} in:\n{body}"))
}

/// Sum of every labelled series in a family (`family{...} value`).
fn prom_sum(body: &str, family: &str) -> f64 {
    let prefix = format!("{family}{{");
    body.lines()
        .filter(|l| l.starts_with(&prefix))
        .filter_map(|l| l.rsplit(' ').next()?.parse::<f64>().ok())
        .sum()
}

// ---------------------------------------------------------------- spans

/// The headline span contract: queue + batch-form + backend-step +
/// deliver are contiguous segments of [oldest enqueue, delivery], so
/// their per-tick counts match `pipeline_total` exactly and their sums
/// reconcile with it to within timer truncation.
#[test]
fn stage_spans_partition_pipeline_total() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4, ObsLevel::Spans)).expect("spawn");
    let h = engine.handle();
    drive(&h, 1, 50);
    let m = h.metrics().expect("metrics");
    engine.shutdown().expect("shutdown");

    let total = m.stage_spans.get(Stage::PipelineTotal);
    assert_eq!(m.ticks, 50, "serial closed loop: one tick per push");
    assert_eq!(total.count(), m.ticks, "one pipeline_total span per tick");
    let parts = [Stage::Queue, Stage::BatchForm, Stage::BackendStep, Stage::Deliver];
    for st in parts {
        assert_eq!(
            m.stage_spans.get(st).count(),
            total.count(),
            "stage {} must record once per tick",
            st.name()
        );
    }
    let part_sum: u64 = parts.iter().map(|&s| m.stage_spans.get(s).sum().as_micros() as u64).sum();
    let whole = total.sum().as_micros() as u64;
    // each span records at µs resolution with a 1µs floor: at most a
    // few µs of slack per tick, nowhere near 16
    let tol = 16 * total.count();
    assert!(
        part_sum.abs_diff(whole) <= tol,
        "stage sums {part_sum}µs do not reconcile with pipeline_total {whole}µs (tol {tol}µs)"
    );
    // ingress records once per accepted token vector
    assert_eq!(m.stage_spans.get(Stage::Ingress).count(), m.tokens_in);
}

#[test]
fn obs_off_records_no_spans_and_no_events() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4, ObsLevel::Off)).expect("spawn");
    let h = engine.handle();
    drive(&h, 1, 10);
    let m = h.metrics().expect("metrics");
    assert_eq!(m.stage_spans.total_count(), 0, "obs=off must not record spans");
    assert_eq!(m.slow_ticks, 0);
    assert!(h.obs().journal().is_empty(), "obs=off must not journal");
    // the pre-existing counters and histograms stay on at every level
    assert_eq!(m.ticks, 10);
    assert_eq!(m.tick_latency.count(), 10);
    assert!(m.queue_latency.count() >= 10);
    engine.shutdown().expect("shutdown");
}

// -------------------------------------------------------------- journal

#[test]
fn journal_captures_lifecycle_in_order() {
    let engine = EngineThread::spawn(cluster_cfg(2, 2, ObsLevel::Journal)).expect("spawn");
    let h = engine.handle();
    let a = h.open().expect("open a");
    let b = h.open().expect("open b");
    let mut rng = Rng::new(0x10A);
    for _ in 0..3 {
        a.push(rng.normal_vec(D_IN, 1.0)).expect("push");
        a.recv_timeout(Duration::from_secs(30)).expect("tick");
    }
    let from = h.shard_of(a.id()).unwrap_or(0);
    h.migrate(a.id(), (from + 1) % 2).expect("migrate");
    let a_id = a.id().0;
    a.close();
    b.close();
    // metrics is a synchronous round-trip through every shard, so the
    // closes above are fully processed before the drain below
    let _ = h.metrics().expect("metrics barrier");

    let events = h.obs().journal().drain();
    let has = |k: EventKind| events.iter().any(|e| e.kind == k);
    assert!(has(EventKind::DispatchResolved), "boot must journal the resolved kernel path");
    assert!(has(EventKind::StreamOpen));
    assert!(has(EventKind::StreamClose));
    assert!(has(EventKind::MigrationAttempt));
    assert!(has(EventKind::MigrationComplete));
    assert!(
        events.iter().any(|e| e.kind == EventKind::MigrationAttempt && e.stream == a_id),
        "the migration attempt must carry the migrated stream id"
    );
    assert!(
        events.windows(2).all(|w| w[0].seq < w[1].seq),
        "drained events must come out in strictly increasing seq order"
    );
    engine.shutdown().expect("shutdown");
}

// ------------------------------------------------------- HTTP endpoint

#[test]
fn metrics_endpoint_serves_live_engine() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4, ObsLevel::Journal)).expect("spawn");
    let h = engine.handle();
    drive(&h, 1, 20);

    let eng = engine.handle();
    let srv = MetricsServer::start("127.0.0.1:0", move |fmt| {
        let obs = eng.obs();
        match fmt {
            MetricsFormat::JournalDrain => expo::render_journal(obs),
            MetricsFormat::Prometheus => match eng.metrics() {
                Ok(m) => expo::render_prometheus(obs, &m, None),
                Err(e) => format!("# metrics unavailable: {e}\n"),
            },
            MetricsFormat::Json => match eng.metrics() {
                Ok(m) => expo::render_json(obs, &m, None),
                Err(e) => format!("{{\"error\":\"{e}\"}}"),
            },
        }
    })
    .expect("start metrics endpoint");
    let addr = srv.local_addr();

    let prom = http_get(addr, "/metrics");
    assert!(prom.starts_with("HTTP/1.0 200"), "{prom}");
    let body = body_of(&prom);
    assert_eq!(prom_value(body, "deepcot_ticks_total"), 20.0);
    assert!(body.contains("deepcot_snapshot_seq"));
    let stage_key = "deepcot_stage_latency_us_count{stage=\"backend_step\"}";
    assert_eq!(prom_value(body, stage_key), 20.0, "one backend_step span per tick");

    // JSON snapshot parses, agrees on the counters, and the snapshot
    // sequence is strictly monotonic across scrapes
    let v1 = Json::parse(body_of(&http_get(addr, "/metrics.json"))).expect("json scrape 1");
    assert_eq!(v1.get("ticks").unwrap().as_f64().unwrap(), 20.0);
    assert!(v1.get("stages").is_some(), "spans are on at obs=journal");
    let v2 = Json::parse(body_of(&http_get(addr, "/metrics.json"))).expect("json scrape 2");
    let (s1, s2) = (
        v1.get("seq").unwrap().as_f64().unwrap(),
        v2.get("seq").unwrap().as_f64().unwrap(),
    );
    assert!(s2 > s1, "snapshot seq must be monotonic ({s1} then {s2})");

    // /journal drains: the first scrape consumes the resident events
    let j1 = body_of(&http_get(addr, "/journal")).to_string();
    Json::parse(&j1).expect("journal is well-formed JSON");
    assert!(!j1.contains("\"events\":[]"), "lifecycle events were resident:\n{j1}");
    let j2 = body_of(&http_get(addr, "/journal")).to_string();
    assert!(j2.contains("\"events\":[]"), "second drain must be empty:\n{j2}");

    assert!(http_get(addr, "/nope").starts_with("HTTP/1.0 404"));
    drop(srv);
    engine.shutdown().expect("shutdown");
}

// ------------------------------------------------------------- the wire

#[test]
fn metrics_prom_frame_serves_the_same_exposition() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4, ObsLevel::Journal)).expect("spawn");
    let server = NetServer::start("127.0.0.1:0", engine.handle()).expect("net server");
    let mut c = NetClient::connect(server.local_addr()).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    let stream = c.open().expect("open");
    let mut rng = Rng::new(0x11FE);
    for _ in 0..5 {
        c.push(stream, &rng.normal_vec(D_IN, 1.0)).expect("push");
        c.recv_tick(stream).expect("tick");
    }
    let prom = c.metrics_prometheus().expect("METRICS_PROM");
    assert_eq!(prom_value(&prom, "deepcot_ticks_total"), 5.0);
    assert!(prom.contains("deepcot_net_frames_in_total"), "net counters ride along:\n{prom}");
    assert!(
        prom.contains("stage=\"net_decode\""),
        "net decode spans must reach the wire exposition:\n{prom}"
    );
    c.close(stream).expect("close");
    server.shutdown();
    engine.shutdown().expect("shutdown");
}

// ------------------------------------------------- snapshot consistency

/// Every exported per-shard series must sum back to its cluster
/// aggregate — both in the `ClusterMetrics` struct and in the rendered
/// Prometheus text a scraper actually sees.
#[test]
fn per_shard_series_sum_to_aggregates() {
    let engine = EngineThread::spawn(cluster_cfg(2, 4, ObsLevel::Journal)).expect("spawn");
    let h = engine.handle();
    drive(&h, 4, 10);
    let m = h.metrics().expect("metrics");

    let sums = |f: fn(&deepcot::coordinator::metrics::EngineMetrics) -> u64| -> u64 {
        m.per_shard.iter().map(f).sum()
    };
    assert_eq!(m.ticks, sums(|s| s.ticks));
    assert_eq!(m.tokens_in, sums(|s| s.tokens_in));
    assert_eq!(m.outputs, sums(|s| s.outputs));
    assert_eq!(m.streams_opened, sums(|s| s.streams_opened));
    assert_eq!(m.streams_closed, sums(|s| s.streams_closed));
    assert_eq!(m.streams_evicted, sums(|s| s.streams_evicted));
    assert_eq!(m.admission_rejects, sums(|s| s.admission_rejects));
    assert_eq!(m.streams_hibernated, sums(|s| s.streams_hibernated));
    assert_eq!(m.streams_restored, sums(|s| s.streams_restored));
    assert_eq!(m.tick_latency.count(), sums(|s| s.tick_latency.count()));
    assert_eq!(
        m.tick_latency.sum().as_micros(),
        m.per_shard.iter().map(|s| s.tick_latency.sum().as_micros()).sum::<u128>()
    );
    assert_eq!(
        m.stage_spans.total_count(),
        m.per_shard.iter().map(|s| s.stage_spans.total_count()).sum::<u64>()
    );

    let body = expo::render_prometheus(h.obs(), &m, None);
    for (shard_family, agg_name) in [
        ("deepcot_shard_ticks_total", "deepcot_ticks_total"),
        ("deepcot_shard_tokens_in_total", "deepcot_tokens_in_total"),
        ("deepcot_shard_outputs_total", "deepcot_outputs_total"),
        ("deepcot_shard_streams_opened_total", "deepcot_streams_opened_total"),
        ("deepcot_shard_streams_closed_total", "deepcot_streams_closed_total"),
        ("deepcot_shard_streams_evicted_total", "deepcot_streams_evicted_total"),
        ("deepcot_shard_admission_rejects_total", "deepcot_admission_rejects_total"),
        ("deepcot_shard_streams_hibernated_total", "deepcot_streams_hibernated_total"),
        ("deepcot_shard_streams_restored_total", "deepcot_streams_restored_total"),
    ] {
        assert_eq!(
            prom_sum(&body, shard_family),
            prom_value(&body, agg_name),
            "{shard_family} must sum to {agg_name}"
        );
    }
    engine.shutdown().expect("shutdown");
}

// ------------------------------------------------------------ properties

fn rand_histo(rng: &mut Rng, max_samples: usize) -> LatencyHisto {
    let mut h = LatencyHisto::new();
    let n = rng.below(max_samples + 1);
    for _ in 0..n {
        // spread samples across the histogram's full log range
        let us = 1u64 + rng.below(1 << rng.below(27)) as u64;
        h.record(Duration::from_micros(us));
    }
    h
}

#[test]
fn prop_quantile_monotone_and_bounded_by_max() {
    prop::check("histo-quantile-monotone", 200, |rng| {
        let mut h = rand_histo(rng, 200);
        h.record(Duration::from_micros(1 + rng.below(1 << 20) as u64)); // never empty
        let mut prev = Duration::ZERO;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = h.quantile(q);
            if v < prev {
                return Err(format!("quantile({q}) = {v:?} dropped below {prev:?}"));
            }
            if v > h.max() {
                return Err(format!("quantile({q}) = {v:?} exceeds max {:?}", h.max()));
            }
            prev = v;
        }
        if h.quantile(1.0) != h.max() {
            return Err(format!("quantile(1.0) {:?} != max {:?}", h.quantile(1.0), h.max()));
        }
        Ok(())
    });
}

#[test]
fn prop_merge_preserves_count_sum_max() {
    prop::check("histo-merge-mass", 200, |rng| {
        let a = rand_histo(rng, 150);
        let b = rand_histo(rng, 150);
        let mut m = a.clone();
        m.merge(&b);
        if m.count() != a.count() + b.count() {
            return Err(format!("count {} != {} + {}", m.count(), a.count(), b.count()));
        }
        if m.sum() != a.sum() + b.sum() {
            return Err(format!("sum {:?} != {:?} + {:?}", m.sum(), a.sum(), b.sum()));
        }
        if m.max() != a.max().max(b.max()) {
            return Err(format!("max {:?} != max({:?}, {:?})", m.max(), a.max(), b.max()));
        }
        if m.count() > 0 && m.quantile(1.0) != m.max() {
            return Err("merged quantile(1.0) != merged max".into());
        }
        // merging an empty histogram is the identity
        let mut e = a.clone();
        e.merge(&LatencyHisto::new());
        if e != a {
            return Err("merge with empty changed the histogram".into());
        }
        Ok(())
    });
}

#[test]
fn prop_journal_stays_bounded() {
    prop::check("journal-bounded", 60, |rng| {
        let cap = rng.below(32) + 1;
        let j = Journal::with_limits(cap, 1_000_000);
        let n = rng.below(200);
        for i in 0..n {
            let kind = EventKind::ALL[rng.below(EventKind::ALL.len())];
            j.push(kind, i as u64, 0, 0);
        }
        if j.len() > cap {
            return Err(format!("journal grew to {} past capacity {cap}", j.len()));
        }
        let stats = j.stats();
        if stats.recorded != n as u64 || stats.len != n.min(cap) as u64 {
            return Err(format!("stats {stats:?} inconsistent with {n} pushes, cap {cap}"));
        }
        let evs = j.drain();
        if evs.len() != n.min(cap) {
            return Err(format!("drained {} events, expected {}", evs.len(), n.min(cap)));
        }
        if !evs.windows(2).all(|w| w[0].seq + 1 == w[1].seq) {
            return Err("drained seqs are not consecutive oldest-first".into());
        }
        if n > 0 && evs.last().unwrap().seq != n as u64 - 1 {
            return Err("the newest event did not survive the overwrites".into());
        }
        Ok(())
    });
}
