//! Cluster integration: sharding must be invisible to streams.
//!
//! The load-bearing property: a stream's `TickResult`s are
//! **bitwise-identical** whether it serves on a 1-shard or an N-shard
//! cluster, under steady traffic and under open/close churn. Per-lane
//! position clocks (a stream's RoPE phases depend only on its own
//! history) plus lane-local attention make this exact, not approximate.
//!
//! Hermetic: serves the `SyntheticServeSpec::default()` artifacts on
//! the batched scalar backend — no XLA shared library, no
//! `make artifacts`. The drivers are deterministic (serial push → recv,
//! one outstanding token cluster-wide), so every tick carries exactly
//! one live lane and timing can't perturb the traces.

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::{EngineThread, TickResult};
use deepcot::coordinator::slots::StreamId;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn cluster_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig {
        variant: SyntheticServeSpec::variant_name(1),
        artifacts_dir: synth_artifacts(),
        backend: EngineBackend::Scalar,
        batch_deadline: Duration::from_millis(1),
        shards,
        slots_per_shard,
        ..EngineConfig::default()
    }
}

fn recv_tick(rx: &std::sync::mpsc::Receiver<TickResult>) -> TickResult {
    rx.recv_timeout(Duration::from_secs(30)).expect("tick result")
}

/// Compare two per-stream traces bit-for-bit (f32 equality is exact:
/// sharding must not change a single ULP).
fn assert_bitwise(label: &str, a: &[Vec<TickResult>], b: &[Vec<TickResult>]) {
    assert_eq!(a.len(), b.len(), "{label}: stream count");
    for (s, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{label}: stream {s} tick count");
        for (t, (ra, rb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(ra.tick, rb.tick, "{label}: stream {s} tick {t} ordinal");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(
                bits(&ra.logits),
                bits(&rb.logits),
                "{label}: stream {s} tick {t} logits diverge"
            );
            assert_eq!(
                bits(&ra.out),
                bits(&rb.out),
                "{label}: stream {s} tick {t} out diverges"
            );
        }
    }
}

/// Steady traffic: every stream ticks every round, driven serially.
fn run_steady_trace(shards: usize, slots_per_shard: usize) -> Vec<Vec<TickResult>> {
    const STREAMS: usize = 6;
    const TICKS: usize = 8;
    let engine = EngineThread::spawn(cluster_cfg(shards, slots_per_shard)).unwrap();
    let h = engine.handle();
    let mut sessions = Vec::new();
    for s in 0..STREAMS {
        let (id, rx) = h.open().unwrap();
        sessions.push((id, rx, Rng::new(1000 + s as u64)));
    }
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); STREAMS];
    for _round in 0..TICKS {
        for (s, (id, rx, rng)) in sessions.iter_mut().enumerate() {
            h.push(*id, rng.normal_vec(D_IN, 1.0)).unwrap();
            traces[s].push(recv_tick(rx));
        }
    }
    for (id, _, _) in &sessions {
        h.close(*id);
    }
    engine.shutdown().unwrap();
    traces
}

#[test]
fn sharded_cluster_is_bitwise_identical_to_single_shard() {
    let single = run_steady_trace(1, 6);
    let quad = run_steady_trace(4, 2);
    assert_bitwise("1 shard vs 4 shards", &single, &quad);
}

/// Open/close churn: streams open mid-run (on whichever shard placement
/// picks), close, and hand their slots to successors. Each logical
/// stream's trace must still be bitwise-independent of the layout.
fn run_churn_trace(shards: usize, slots_per_shard: usize) -> Vec<Vec<TickResult>> {
    const LOGICAL: usize = 6;
    let engine = EngineThread::spawn(cluster_cfg(shards, slots_per_shard)).unwrap();
    let h = engine.handle();
    let mut sessions: Vec<Option<(StreamId, std::sync::mpsc::Receiver<TickResult>)>> =
        (0..LOGICAL).map(|_| None).collect();
    let mut rngs: Vec<Rng> = (0..LOGICAL).map(|s| Rng::new(2000 + s as u64)).collect();
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); LOGICAL];
    for sess in sessions.iter_mut().take(4) {
        *sess = Some(h.open().unwrap());
    }
    for round in 0..12 {
        if round == 4 {
            // L1/L3 leave; L4 takes a recycled slot mid-run
            for s in [1, 3] {
                let (id, _rx) = sessions[s].take().unwrap();
                h.close(id);
            }
            sessions[4] = Some(h.open().unwrap());
        }
        if round == 8 {
            let (id, _rx) = sessions[0].take().unwrap();
            h.close(id);
            sessions[5] = Some(h.open().unwrap());
        }
        for ((sess, rng), trace) in sessions.iter().zip(rngs.iter_mut()).zip(traces.iter_mut()) {
            if let Some((id, rx)) = sess {
                h.push(*id, rng.normal_vec(D_IN, 1.0)).unwrap();
                trace.push(recv_tick(rx));
            }
        }
    }
    for sess in sessions.iter().flatten() {
        h.close(sess.0);
    }
    engine.shutdown().unwrap();
    traces
}

#[test]
fn churned_streams_are_bitwise_identical_across_layouts() {
    let single = run_churn_trace(1, 4);
    let quad = run_churn_trace(4, 1);
    let dual = run_churn_trace(2, 2);
    // sanity: the schedule produced the intended tick counts
    assert_eq!(single[0].len(), 8);
    assert_eq!(single[1].len(), 4);
    assert_eq!(single[4].len(), 8);
    assert_eq!(single[5].len(), 4);
    assert_bitwise("churn: 1 shard vs 4 shards", &single, &quad);
    assert_bitwise("churn: 1 shard vs 2 shards", &single, &dual);
}

/// Concurrent smoke: a 4-shard cluster must serve parallel closed-loop
/// clients to completion with coherent cluster metrics.
#[test]
fn four_shard_cluster_serves_concurrent_clients() {
    let engine = EngineThread::spawn(cluster_cfg(4, 2)).unwrap();
    let h = engine.handle();
    // open all sessions up front so the per-shard placement assertions
    // below are deterministic (8 streams over 4x2 slots: exactly 2 per
    // shard by pigeonhole, regardless of client scheduling)
    let sessions: Vec<_> = (0..8).map(|_| h.open().unwrap()).collect();
    let mut clients = Vec::new();
    for (s, (id, rx)) in sessions.into_iter().enumerate() {
        let h = h.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(s as u64);
            for t in 0..20 {
                h.push(id, rng.normal_vec(D_IN, 1.0)).unwrap();
                let out = recv_tick(&rx);
                assert_eq!(out.tick, t + 1);
                assert!(out.logits.iter().all(|v| v.is_finite()));
                assert!(out.out.iter().all(|v| v.is_finite()));
            }
            h.close(id);
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let m = h.metrics().unwrap();
    assert_eq!(m.outputs, 160);
    assert_eq!(m.streams_opened, 8);
    assert_eq!(m.per_shard.len(), 4);
    assert_eq!(m.per_shard.iter().map(|s| s.outputs).sum::<u64>(), 160);
    // 8 streams over 4 shards of 2 slots: capacity forces full spread
    for (i, sm) in m.per_shard.iter().enumerate() {
        assert_eq!(sm.streams_opened, 2, "shard {i} should hold exactly 2 streams");
    }
    assert_eq!(m.placed_primary + m.placed_fallback, 8);
    engine.shutdown().unwrap();
}

/// A full primary shard hands the stream to a fallback; a fully
/// saturated cluster rejects and says so in the metrics.
#[test]
fn placement_falls_back_then_rejects_when_full() {
    let engine = EngineThread::spawn(cluster_cfg(2, 1)).unwrap();
    let h = engine.handle();
    let (a, _rx_a) = h.open().unwrap();
    let (b, _rx_b) = h.open().unwrap();
    let err = h.open().expect_err("third open must be rejected at 2x1 capacity");
    assert!(err.to_string().contains("no free slots"), "unexpected error: {err}");
    let m = h.metrics().unwrap();
    assert_eq!(m.placed_primary + m.placed_fallback, 2);
    assert_eq!(m.cluster_rejects, 1);
    // the rejected open consulted every shard
    assert!(m.admission_rejects >= 2, "got {} shard-level rejects", m.admission_rejects);
    h.close(a);
    h.close(b);
    // capacity returns after close (close is async; retry briefly)
    let mut reopened = None;
    for _ in 0..50 {
        match h.open() {
            Ok(p) => {
                reopened = Some(p);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let (c, rx_c) = reopened.expect("slot should free after close");
    let mut rng = Rng::new(3);
    h.push(c, rng.normal_vec(D_IN, 1.0)).unwrap();
    recv_tick(&rx_c);
    h.close(c);
    engine.shutdown().unwrap();
}

/// Idle eviction must tear the stream down everywhere: the victim's
/// output channel disconnects, its front-door binding is reclaimed (a
/// push to it fails at the front door), and a late close by its owner
/// does not double-count it as closed on top of evicted.
#[test]
fn idle_eviction_reconciles_front_door_and_counts_once() {
    let mut cfg = cluster_cfg(1, 1);
    cfg.idle_timeout = Duration::from_millis(10);
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let (a, rx_a) = h.open().unwrap();
    std::thread::sleep(Duration::from_millis(30));
    // single slot, A idle past the timeout: this open evicts A
    let (b, _rx_b) = h.open().unwrap();
    assert!(
        rx_a.recv_timeout(Duration::from_millis(200)).is_err(),
        "evicted stream's output channel must disconnect"
    );
    let err = h.push(a, vec![0.0; D_IN]).expect_err("push to an evicted stream must fail");
    assert!(err.to_string().contains("unknown stream"), "unexpected error: {err}");
    h.close(a); // late close of the evicted stream: harmless no-op
    let m = h.metrics().unwrap();
    assert_eq!(m.streams_opened, 2);
    assert_eq!(m.streams_evicted, 1);
    assert_eq!(m.streams_closed, 0, "evicted stream must not also count as closed");
    h.close(b);
    engine.shutdown().unwrap();
}

/// Shutdown must answer every in-flight push with a terminal error —
/// never leave a producer blocked on a reply, never silently drop a
/// queued tick without telling its owner.
#[test]
fn shutdown_drains_inflight_pushes_with_terminal_errors() {
    let engine = EngineThread::spawn(cluster_cfg(2, 2)).unwrap();
    let h = engine.handle();
    let mut producers = Vec::new();
    for s in 0..4u64 {
        let h = h.clone();
        producers.push(std::thread::spawn(move || -> String {
            let mut rng = Rng::new(s);
            let (id, _rx) = match h.open() {
                Ok(pair) => pair,
                // a producer scheduled after shutdown sees the shard's
                // terminal open error — a valid outcome for this test
                Err(e) => return e.to_string(),
            };
            // fire-and-forget producer: never consumes results, so the
            // queue oscillates around the backpressure bound while the
            // main thread shuts the engine down underneath us (the
            // iteration bound only exists to end the test if shutdown
            // somehow never turns pushes terminal)
            for _ in 0..5_000_000u64 {
                match h.push(id, rng.normal_vec(D_IN, 1.0)) {
                    Ok(()) => {}
                    Err(e) => {
                        let msg = e.to_string();
                        if msg.contains("queue full") {
                            std::thread::sleep(Duration::from_micros(50));
                            continue;
                        }
                        return msg; // terminal: engine went away
                    }
                }
            }
            "producer outlived the engine".to_string()
        }));
    }
    std::thread::sleep(Duration::from_millis(50));
    engine.shutdown().unwrap();
    for p in producers {
        let msg = p.join().expect("producer must not hang or panic");
        assert!(
            msg.contains("shut") || msg.contains("gone") || msg.contains("reply"),
            "producer ended without a terminal shutdown error: {msg:?}"
        );
    }
}
