//! Cluster integration: sharding AND live migration must be invisible
//! to streams.
//!
//! The load-bearing property: a stream's `TickResult`s are
//! **bitwise-identical** whether it serves on a 1-shard or an N-shard
//! cluster, under steady traffic, under open/close churn, and across
//! mid-run `migrate()` calls that move its state between shards.
//! Per-lane position clocks (a stream's RoPE phases depend only on its
//! own history) plus lane-local attention plus memcpy'd `StreamState`
//! snapshots make this exact, not approximate.
//!
//! Hermetic: serves the `SyntheticServeSpec::default()` artifacts on
//! the batched scalar backend — no XLA shared library, no
//! `make artifacts`. The drivers are deterministic (serial push → recv,
//! one outstanding token cluster-wide), so every tick carries exactly
//! one live lane and timing can't perturb the traces.

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::{EngineError, EngineThread, Session, TickResult};
use deepcot::manifest::Manifest;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::Mat;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn cluster_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .build()
}

fn recv_tick(sess: &Session) -> TickResult {
    sess.recv_timeout(Duration::from_secs(30)).expect("tick result")
}

/// Compare two per-stream traces bit-for-bit (f32 equality is exact:
/// neither sharding nor migration may change a single ULP).
fn assert_bitwise(label: &str, a: &[Vec<TickResult>], b: &[Vec<TickResult>]) {
    assert_eq!(a.len(), b.len(), "{label}: stream count");
    for (s, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{label}: stream {s} tick count");
        for (t, (ra, rb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(ra.tick, rb.tick, "{label}: stream {s} tick {t} ordinal");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(
                bits(&ra.logits),
                bits(&rb.logits),
                "{label}: stream {s} tick {t} logits diverge"
            );
            assert_eq!(
                bits(&ra.out),
                bits(&rb.out),
                "{label}: stream {s} tick {t} out diverges"
            );
        }
    }
}

/// Steady traffic: every stream ticks every round, driven serially.
/// `migrate_at` entries `(round, stream_index)` hop that stream to the
/// next shard (round-robin) before the given round.
fn run_steady_trace(
    shards: usize,
    slots_per_shard: usize,
    migrate_at: &[(usize, usize)],
) -> Vec<Vec<TickResult>> {
    const STREAMS: usize = 6;
    const TICKS: usize = 8;
    let engine = EngineThread::spawn(cluster_cfg(shards, slots_per_shard)).unwrap();
    let h = engine.handle();
    let mut sessions = Vec::new();
    for s in 0..STREAMS {
        let sess = h.open().unwrap();
        sessions.push((sess, Rng::new(1000 + s as u64)));
    }
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); STREAMS];
    for round in 0..TICKS {
        for &(r, s) in migrate_at {
            if r == round {
                let id = sessions[s].0.id();
                let from = h.shard_of(id).expect("stream bound");
                h.migrate(id, (from + 1) % shards).unwrap();
            }
        }
        for (s, (sess, rng)) in sessions.iter_mut().enumerate() {
            sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
            traces[s].push(recv_tick(sess));
        }
    }
    for (sess, _) in sessions {
        sess.close();
    }
    engine.shutdown().unwrap();
    traces
}

#[test]
fn sharded_cluster_is_bitwise_identical_to_single_shard() {
    let single = run_steady_trace(1, 6, &[]);
    let quad = run_steady_trace(4, 2, &[]);
    assert_bitwise("1 shard vs 4 shards", &single, &quad);
}

/// The acceptance property for live migration: a stream migrated
/// between shards mid-run produces bitwise-identical `TickResult`s to
/// an unmigrated run — under steady traffic here, under churn below.
#[test]
fn migrated_streams_are_bitwise_identical_steady() {
    let reference = run_steady_trace(2, 6, &[]);
    // stream 0 hops away and back; stream 3 hops once; stream 5 hops
    // twice in consecutive rounds
    let migrated = run_steady_trace(2, 6, &[(2, 0), (5, 0), (3, 3), (4, 5), (5, 5)]);
    assert_bitwise("steady: migrated vs unmigrated", &reference, &migrated);
    // and the whole cluster layout stays irrelevant
    let single = run_steady_trace(1, 6, &[]);
    assert_bitwise("steady: migrated vs 1 shard", &single, &migrated);
}

/// Open/close churn: streams open mid-run (on whichever shard placement
/// picks), close, and hand their slots to successors; optionally some
/// survivors migrate mid-run. Each logical stream's trace must still be
/// bitwise-independent of the layout and of any migrations.
fn run_churn_trace(shards: usize, slots_per_shard: usize, migrate: bool) -> Vec<Vec<TickResult>> {
    const LOGICAL: usize = 6;
    let engine = EngineThread::spawn(cluster_cfg(shards, slots_per_shard)).unwrap();
    let h = engine.handle();
    let mut sessions: Vec<Option<Session>> = (0..LOGICAL).map(|_| None).collect();
    let mut rngs: Vec<Rng> = (0..LOGICAL).map(|s| Rng::new(2000 + s as u64)).collect();
    let mut traces: Vec<Vec<TickResult>> = vec![Vec::new(); LOGICAL];
    for sess in sessions.iter_mut().take(4) {
        *sess = Some(h.open().unwrap());
    }
    for round in 0..12 {
        if round == 4 {
            // L1/L3 leave; L4 takes a recycled slot mid-run
            for s in [1, 3] {
                sessions[s].take().unwrap().close();
            }
            sessions[4] = Some(h.open().unwrap());
        }
        if round == 8 {
            sessions[0].take().unwrap().close();
            sessions[5] = Some(h.open().unwrap());
        }
        if migrate && (round == 3 || round == 9) {
            // hop every live stream to its neighbor shard
            for sess in sessions.iter().flatten() {
                let from = h.shard_of(sess.id()).expect("stream bound");
                h.migrate(sess.id(), (from + 1) % shards).unwrap();
            }
        }
        for ((sess, rng), trace) in sessions.iter().zip(rngs.iter_mut()).zip(traces.iter_mut()) {
            if let Some(sess) = sess {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                trace.push(recv_tick(sess));
            }
        }
    }
    for sess in sessions.into_iter().flatten() {
        sess.close();
    }
    engine.shutdown().unwrap();
    traces
}

#[test]
fn churned_streams_are_bitwise_identical_across_layouts() {
    let single = run_churn_trace(1, 4, false);
    let quad = run_churn_trace(4, 1, false);
    let dual = run_churn_trace(2, 2, false);
    // sanity: the schedule produced the intended tick counts
    assert_eq!(single[0].len(), 8);
    assert_eq!(single[1].len(), 4);
    assert_eq!(single[4].len(), 8);
    assert_eq!(single[5].len(), 4);
    assert_bitwise("churn: 1 shard vs 4 shards", &single, &quad);
    assert_bitwise("churn: 1 shard vs 2 shards", &single, &dual);
}

#[test]
fn migrated_streams_are_bitwise_identical_under_churn() {
    let reference = run_churn_trace(1, 4, false);
    // migration needs somewhere to hop: 2 shards with headroom
    let migrated = run_churn_trace(2, 4, true);
    assert_bitwise("churn: migrated vs unmigrated", &reference, &migrated);
}

/// Dropping a `Session` must close its stream and free the slot — the
/// RAII contract.
#[test]
fn session_drop_closes_stream() {
    let engine = EngineThread::spawn(cluster_cfg(1, 1)).unwrap();
    let h = engine.handle();
    let sess = h.open().unwrap();
    let first_id = sess.id();
    drop(sess);
    // close is async; retry briefly until the slot frees
    let mut reopened = None;
    for _ in 0..50 {
        match h.open() {
            Ok(s) => {
                reopened = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let sess2 = reopened.expect("dropping the session must free its slot");
    assert_ne!(sess2.id(), first_id, "ids are cluster-unique, never recycled");
    let mut rng = Rng::new(3);
    sess2.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    recv_tick(&sess2);
    let m = h.metrics().unwrap();
    assert_eq!(m.streams_opened, 2);
    assert_eq!(m.streams_closed, 1, "drop must register as a close");
    sess2.close();
    engine.shutdown().unwrap();
}

/// Migration bookkeeping: counters, per-shard in/out, loads, and the
/// typed errors for bad requests.
#[test]
fn migration_metrics_and_errors() {
    let engine = EngineThread::spawn(cluster_cfg(2, 2)).unwrap();
    let h = engine.handle();
    let a = h.open().unwrap();
    let b = h.open().unwrap();
    let mut rng = Rng::new(11);
    for sess in [&a, &b] {
        sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
        recv_tick(sess);
    }
    // put both streams on the same shard (at most one real move)
    let target = h.shard_of(a.id()).unwrap();
    let real_move = u64::from(h.shard_of(b.id()) != Some(target));
    h.migrate(b.id(), target).unwrap();
    assert_eq!(h.shard_of(b.id()), Some(target));
    let loads = h.shard_loads();
    assert_eq!(loads[target], 2, "both streams tracked on the target shard");
    assert_eq!(loads[1 - target], 0);
    // the migrated stream keeps serving
    b.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    let out = recv_tick(&b);
    assert_eq!(out.tick, 2, "tick ordinal survives migration");
    // typed errors: unknown stream / out-of-range target
    let unknown = deepcot::coordinator::slots::StreamId(9999);
    assert!(matches!(h.migrate(unknown, 0), Err(EngineError::StreamClosed(_))));
    assert!(matches!(h.migrate(a.id(), 7), Err(EngineError::InvalidRequest(_))));
    let m = h.metrics().unwrap();
    // a same-shard migrate is an uncounted no-op, so every counter
    // scales with whether b actually moved; the unknown-stream attempt
    // counts as aborted; the out-of-range target is rejected before it
    // becomes an attempt
    assert_eq!(m.migrations_completed, real_move);
    assert_eq!(m.migrations_attempted, real_move + 1);
    assert_eq!(m.migrations_aborted, 1);
    assert_eq!(
        m.quiesce_latency.count(),
        real_move,
        "one quiesce window per completed migration"
    );
    let (ins, outs): (u64, u64) = m
        .per_shard
        .iter()
        .fold((0, 0), |(i, o), s| (i + s.migrations_in, o + s.migrations_out));
    assert_eq!((ins, outs), (real_move, real_move), "per-shard in/out must balance");
    a.close();
    b.close();
    engine.shutdown().unwrap();
}

/// `rebalance` must walk streams off an overloaded shard until no shard
/// holds ≥2 more than the lightest.
#[test]
fn rebalance_clears_load_skew() {
    let engine = EngineThread::spawn(cluster_cfg(2, 4)).unwrap();
    let h = engine.handle();
    let sessions: Vec<Session> = (0..4).map(|_| h.open().unwrap()).collect();
    let mut rng = Rng::new(21);
    for sess in &sessions {
        sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
        recv_tick(sess);
    }
    // skew everything onto shard 0
    for sess in &sessions {
        h.migrate(sess.id(), 0).unwrap();
    }
    assert_eq!(h.shard_loads(), vec![4, 0]);
    let report = h.rebalance().unwrap();
    assert_eq!(report.planned, 2, "4-0 balances with two moves");
    assert_eq!(report.moved, 2);
    assert_eq!(report.failed, 0);
    assert_eq!(h.shard_loads(), vec![2, 2]);
    // balanced cluster: rebalance is a no-op
    let report = h.rebalance().unwrap();
    assert_eq!(report, deepcot::coordinator::engine::RebalanceReport::default());
    // every stream still serves, bitwise-correct ordinals included
    for (i, sess) in sessions.iter().enumerate() {
        sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
        let out = recv_tick(sess);
        assert_eq!(out.tick, 2, "stream {i} lost ticks across rebalance");
    }
    for sess in sessions {
        sess.close();
    }
    engine.shutdown().unwrap();
}

/// Concurrent smoke: a 4-shard cluster must serve parallel closed-loop
/// clients to completion with coherent cluster metrics.
#[test]
fn four_shard_cluster_serves_concurrent_clients() {
    let engine = EngineThread::spawn(cluster_cfg(4, 2)).unwrap();
    let h = engine.handle();
    // open all sessions up front so the per-shard placement assertions
    // below are deterministic (8 streams over 4x2 slots: exactly 2 per
    // shard by pigeonhole, regardless of client scheduling)
    let sessions: Vec<Session> = (0..8).map(|_| h.open().unwrap()).collect();
    let mut clients = Vec::new();
    for (s, sess) in sessions.into_iter().enumerate() {
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(s as u64);
            for t in 0..20 {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                let out = recv_tick(&sess);
                assert_eq!(out.tick, t + 1);
                assert!(out.logits.iter().all(|v| v.is_finite()));
                assert!(out.out.iter().all(|v| v.is_finite()));
            }
            sess.close();
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let m = h.metrics().unwrap();
    assert_eq!(m.outputs, 160);
    assert_eq!(m.streams_opened, 8);
    assert_eq!(m.per_shard.len(), 4);
    assert_eq!(m.per_shard.iter().map(|s| s.outputs).sum::<u64>(), 160);
    // 8 streams over 4 shards of 2 slots: capacity forces full spread
    for (i, sm) in m.per_shard.iter().enumerate() {
        assert_eq!(sm.streams_opened, 2, "shard {i} should hold exactly 2 streams");
    }
    assert_eq!(m.placed_primary + m.placed_fallback, 8);
    engine.shutdown().unwrap();
}

/// A full primary shard hands the stream to a fallback; a fully
/// saturated cluster rejects with the typed error and says so in the
/// metrics.
#[test]
fn placement_falls_back_then_rejects_when_full() {
    let engine = EngineThread::spawn(cluster_cfg(2, 1)).unwrap();
    let h = engine.handle();
    let a = h.open().unwrap();
    let b = h.open().unwrap();
    let err = h.open().expect_err("third open must be rejected at 2x1 capacity");
    assert!(
        matches!(err, EngineError::Saturated { capacity: 1 }),
        "want Saturated, got: {err:?}"
    );
    // a saturated cluster also rejects migrations into it
    let err = h
        .migrate(a.id(), (h.shard_of(a.id()).unwrap() + 1) % 2)
        .expect_err("migration into a full shard must abort");
    assert!(matches!(err, EngineError::Saturated { .. }), "want Saturated, got: {err:?}");
    let m = h.metrics().unwrap();
    assert_eq!(m.placed_primary + m.placed_fallback, 2);
    assert_eq!(m.cluster_rejects, 1);
    assert_eq!(m.migrations_aborted, 1);
    // the rejected open consulted every shard
    assert!(m.admission_rejects >= 2, "got {} shard-level rejects", m.admission_rejects);
    // the aborted migration put the stream back: it must still serve
    let mut rng = Rng::new(3);
    a.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    recv_tick(&a);
    a.close();
    b.close();
    // capacity returns after close (close is async; retry briefly)
    let mut reopened = None;
    for _ in 0..50 {
        match h.open() {
            Ok(s) => {
                reopened = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let c = reopened.expect("slot should free after close");
    c.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    recv_tick(&c);
    c.close();
    engine.shutdown().unwrap();
}

/// Idle eviction must tear the stream down everywhere: the victim's
/// output channel disconnects, its front-door binding is reclaimed (a
/// push on its session fails with the typed error), and a late close by
/// its owner does not double-count it as closed on top of evicted.
#[test]
fn idle_eviction_reconciles_front_door_and_counts_once() {
    let mut cfg = cluster_cfg(1, 1);
    cfg.idle_timeout = Duration::from_millis(10);
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let a = h.open().unwrap();
    std::thread::sleep(Duration::from_millis(30));
    // single slot, A idle past the timeout: this open evicts A
    let b = h.open().unwrap();
    assert!(
        matches!(a.recv_timeout(Duration::from_millis(200)), Err(EngineError::StreamClosed(_))),
        "evicted stream's output channel must disconnect"
    );
    let err = a.push(vec![0.0; D_IN]).expect_err("push on an evicted stream must fail");
    assert!(matches!(err, EngineError::StreamClosed(_)), "want StreamClosed, got {err:?}");
    a.close(); // late close of the evicted stream: harmless no-op
    let m = h.metrics().unwrap();
    assert_eq!(m.streams_opened, 2);
    assert_eq!(m.streams_evicted, 1);
    assert_eq!(m.streams_closed, 0, "evicted stream must not also count as closed");
    b.close();
    engine.shutdown().unwrap();
}

/// Randomized open/push/migrate/close interleaving (seeded, ≥1k ops)
/// on a 3-shard cluster, checked against a single-threaded oracle:
/// every live stream carries its own 1-lane `BatchedScalarDeepCoT`
/// stepped in lockstep with its pushes. Whatever placement, eviction
/// headroom, and migration the schedule hits, each stream's engine
/// outputs must stay bitwise equal to its isolated oracle — the
/// concurrency-coverage gap the steady/churn traces above leave open.
#[test]
fn randomized_interleaving_matches_single_stream_oracle() {
    let (manifest, mdir) = Manifest::load(&synth_artifacts()).unwrap();
    let entry = manifest.variant(&SyntheticServeSpec::variant_name(1)).unwrap();
    let params = ModelParams::load(&mdir, entry).unwrap();
    let mc = entry.config.clone();
    let engine = EngineThread::spawn(cluster_cfg(3, 3)).unwrap(); // 9 slots
    let h = engine.handle();

    struct LiveStream {
        sess: Session,
        rng: Rng,
        oracle: BatchedScalarDeepCoT,
        pos: i32,
        ticks: u64,
    }
    let mut rng = Rng::new(0xC0FFEE);
    let mut live: Vec<LiveStream> = Vec::new();
    let (mut opened, mut pushed, mut migrated, mut closed, mut saturated) = (0, 0, 0, 0, 0u64);
    const OPS: usize = 1200;
    for _ in 0..OPS {
        match rng.below(10) {
            0 | 1 => match h.open() {
                Ok(sess) => {
                    live.push(LiveStream {
                        sess,
                        rng: rng.fork(),
                        oracle: BatchedScalarDeepCoT::with_lanes(mc.clone(), params.clone(), 1),
                        pos: 0,
                        ticks: 0,
                    });
                    opened += 1;
                }
                Err(EngineError::Saturated { .. }) => saturated += 1,
                Err(e) => panic!("open failed with a non-saturation error: {e:?}"),
            },
            2 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    live.swap_remove(i).sess.close();
                    closed += 1;
                }
            }
            3 | 4 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    let id = live[i].sess.id();
                    // a same-shard pick is a no-op, a full target
                    // aborts with the stream intact — both fine here
                    let _ = h.migrate(id, rng.below(3));
                    migrated += 1;
                }
            }
            _ => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    let l = &mut live[i];
                    let toks = l.rng.normal_vec(mc.d_in, 1.0);
                    l.sess.push(toks.clone()).unwrap();
                    let got = l.sess.recv_timeout(Duration::from_secs(30)).unwrap();
                    let tokens = Mat::from_vec(1, mc.d_in, toks);
                    let step = l.oracle.tick_lanes(&tokens, &[true], &[l.pos]).unwrap();
                    let logits_want: Vec<u32> =
                        step.logits.row(0).iter().map(|v| v.to_bits()).collect();
                    let out_want: Vec<u32> = (0..mc.m_tokens)
                        .flat_map(|r| step.out.row(r).iter().map(|v| v.to_bits()))
                        .collect();
                    let logits_got: Vec<u32> = got.logits.iter().map(|v| v.to_bits()).collect();
                    let out_got: Vec<u32> = got.out.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(logits_got, logits_want, "stream {} logits diverge", l.sess.id().0);
                    assert_eq!(out_got, out_want, "stream {} out diverges", l.sess.id().0);
                    l.pos += 1;
                    l.ticks += 1;
                    assert_eq!(got.tick, l.ticks, "stream {} tick ordinal", l.sess.id().0);
                    pushed += 1;
                }
            }
        }
    }
    assert!(
        pushed >= 300 && opened >= 30 && migrated >= 60 && closed >= 30,
        "schedule under-exercised: pushed={pushed} opened={opened} \
         migrated={migrated} closed={closed} saturated={saturated}"
    );
    drop(live); // sessions close on drop
    engine.shutdown().unwrap();
}

/// Shutdown must answer every in-flight push with a terminal typed
/// error — never leave a producer blocked on a reply, never silently
/// drop a queued tick without telling its owner.
#[test]
fn shutdown_drains_inflight_pushes_with_terminal_errors() {
    let engine = EngineThread::spawn(cluster_cfg(2, 2)).unwrap();
    let h = engine.handle();
    let mut producers = Vec::new();
    for s in 0..4u64 {
        let h = h.clone();
        producers.push(std::thread::spawn(move || -> EngineError {
            let mut rng = Rng::new(s);
            let sess = match h.open() {
                Ok(sess) => sess,
                // a producer scheduled after shutdown sees the shard's
                // terminal open error — a valid outcome for this test
                Err(e) => return e,
            };
            // fire-and-forget producer: never consumes results, so the
            // queue oscillates around the backpressure bound while the
            // main thread shuts the engine down underneath us (the
            // iteration bound only exists to end the test if shutdown
            // somehow never turns pushes terminal)
            for _ in 0..5_000_000u64 {
                match sess.push(rng.normal_vec(D_IN, 1.0)) {
                    Ok(()) => {}
                    Err(EngineError::Backpressure(_)) => {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    Err(e) => return e, // terminal: engine went away
                }
            }
            EngineError::Internal("producer outlived the engine".into())
        }));
    }
    std::thread::sleep(Duration::from_millis(50));
    engine.shutdown().unwrap();
    for p in producers {
        let err = p.join().expect("producer must not hang or panic");
        assert!(
            matches!(err, EngineError::ShuttingDown | EngineError::StreamClosed(_)),
            "producer ended without a terminal shutdown error: {err:?}"
        );
    }
}
