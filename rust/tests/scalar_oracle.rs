//! Triangulation: the pure-Rust scalar engine must match the JAX host
//! reference (golden dumps) with no PJRT in the loop — an independent
//! implementation of the same numerics (DESIGN.md §3, nn module).

use anyhow::{Context, Result};

use deepcot::manifest::Manifest;
use deepcot::nn::encoder::{encoder_forward, ScalarDeepCoT};
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::Mat;
use deepcot::util::json::Json;

const RTOL: f32 = 3e-3;
const ATOL: f32 = 3e-3;

/// Golden dumps come from `make artifacts` (the JAX side). Absent
/// artifacts there is nothing to triangulate against — skip instead of
/// failing, so the hermetic test suite stays green in XLA-less
/// environments. (tests/scalar_continual.rs covers the scalar engine
/// hermetically.)
fn artifacts_available() -> bool {
    let ok = deepcot::artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping golden-oracle test: no artifacts (run `make artifacts`)");
    }
    ok
}

struct Golden {
    ticks: usize,
    stream: Vec<Vec<f32>>,
    logits: Vec<Vec<f32>>,
    out_last: Vec<Vec<f32>>,
}

fn load(name: &str) -> Result<(deepcot::manifest::VariantEntry, ModelParams, Golden)> {
    let dir = deepcot::artifacts_dir();
    let (m, _) = Manifest::load(&dir)?;
    let entry = m.variant(name)?.clone();
    let params = ModelParams::load(&dir, &entry)?;
    let text = std::fs::read_to_string(
        dir.join(entry.golden.clone().context("no golden")?),
    )?;
    let v = Json::parse(&text)?;
    let rows = |key: &str| -> Result<Vec<Vec<f32>>> {
        v.req(key)?.as_arr()?.iter().map(|r| r.as_f32_vec()).collect()
    };
    let g = Golden {
        ticks: v.req("ticks")?.as_usize()?,
        stream: rows("stream")?,
        logits: rows("expected_logits")?,
        out_last: rows("expected_out_last")?,
    };
    Ok((entry, params, g))
}

fn assert_close(what: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{what} length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = ATOL + RTOL * w.abs();
        assert!((g - w).abs() <= tol, "{what}[{i}]: got {g}, want {w}");
    }
}

fn check_deepcot(name: &str) -> Result<()> {
    let (entry, params, g) = load(name)?;
    let cfg = entry.config.clone();
    // scalar engine is single-lane; run each batch lane separately
    for lane in 0..cfg.batch {
        let mut eng = ScalarDeepCoT::new(cfg.clone(), params.clone());
        for t in 0..g.ticks {
            let row = &g.stream[t];
            let lane_elems = cfg.m_tokens * cfg.d_in;
            let chunk = &row[lane * lane_elems..(lane + 1) * lane_elems];
            let tokens = Mat::from_vec(cfg.m_tokens, cfg.d_in, chunk.to_vec());
            let (logits, out) = eng.tick(&tokens)?;
            let c = cfg.n_classes;
            assert_close(
                &format!("{name} lane {lane} tick {t} logits"),
                logits,
                &g.logits[t][lane * c..(lane + 1) * c],
            );
            let d = cfg.d_model;
            assert_close(
                &format!("{name} lane {lane} tick {t} out"),
                &out.data[(cfg.m_tokens - 1) * d..],
                &g.out_last[t][lane * d..(lane + 1) * d],
            );
        }
    }
    Ok(())
}

#[test]
fn scalar_deepcot_matches_jax_golden() {
    if !artifacts_available() {
        return;
    }
    check_deepcot("tiny_deepcot").unwrap();
}

#[test]
fn scalar_deepcot_l1_matches_jax_golden() {
    if !artifacts_available() {
        return;
    }
    check_deepcot("tiny_deepcot_l1").unwrap();
}

#[test]
fn scalar_deepcot_soft_matches_jax_golden() {
    if !artifacts_available() {
        return;
    }
    check_deepcot("tiny_deepcot_soft").unwrap();
}

#[test]
fn scalar_deepcot_m3_matches_jax_golden() {
    if !artifacts_available() {
        return;
    }
    check_deepcot("tiny_deepcot_m3").unwrap();
}

#[test]
fn scalar_encoder_matches_jax_golden() {
    if !artifacts_available() {
        return;
    }
    let (entry, params, g) = load("tiny_encoder").unwrap();
    let cfg = entry.config.clone();
    let n = cfg.window;
    // replay the sliding window with zero left-padding (the shared
    // cold-start convention) per batch lane
    for lane in 0..cfg.batch {
        let mut history: Vec<Vec<f32>> = Vec::new();
        for t in 0..g.ticks {
            let row = &g.stream[t];
            history.push(row[lane * cfg.d_in..(lane + 1) * cfg.d_in].to_vec());
            let mut win = Mat::zeros(n, cfg.d_in);
            let have = history.len().min(n);
            for j in 0..have {
                let src = &history[history.len() - have + j];
                win.row_mut(n - have + j).copy_from_slice(src);
            }
            let pos0 = t as i32 - (n as i32 - 1);
            let (logits, out) = encoder_forward(&cfg, &params, &win, pos0).unwrap();
            let c = cfg.n_classes;
            assert_close(
                &format!("encoder lane {lane} tick {t} logits"),
                &logits,
                &g.logits[t][lane * c..(lane + 1) * c],
            );
            let d = cfg.d_model;
            assert_close(
                &format!("encoder lane {lane} tick {t} out"),
                out.row(n - 1),
                &g.out_last[t][lane * d..(lane + 1) * d],
            );
        }
    }
}
