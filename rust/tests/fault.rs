//! Fault-isolation integration: a crashing shard worker must be a
//! contained, typed, recoverable event — never a poisoned engine.
//!
//! The subsystem's acceptance properties, pinned end to end:
//!
//! 1. An injected shard-worker panic (deterministic `shard_step=@N`
//!    plan) kills exactly one shard. Streams on the survivors keep
//!    serving **bitwise-identically**; the crashed shard's streams
//!    come back via resume from their last checkpoint, and the
//!    concatenated per-stream traces still match a scalar oracle
//!    replay bit for bit. The supervisor re-homes, respawns, and the
//!    engine never reports `ShuttingDown` while healthy.
//! 2. A ≥500-op chaos run over a slot-starved hibernating cluster
//!    with seeded store faults (failing puts + syncs, a torn log
//!    tail) stays bitwise-exact against the oracle: store failures
//!    degrade durability, never correctness or availability.
//! 3. `EngineError::ShardFailed` survives the wire byte-exactly
//!    (code 10, aux = retryable flag), and a TCP client can ride
//!    through a mid-load shard crash using only typed errors +
//!    OPEN-resume.
//!
//! Hermetic: `SyntheticServeSpec::default()` artifacts on the batched
//! scalar backend, explicit fault plans (env-independent), serial
//! drivers, deterministic seeds throughout.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use deepcot::config::{EngineBackend, EngineConfig, PlacementPolicy};
use deepcot::coordinator::engine::{EngineError, EngineHandle, EngineThread, Session, TickResult};
use deepcot::coordinator::slots::StreamId;
use deepcot::fault::FaultPlan;
use deepcot::manifest::Manifest;
use deepcot::net::client::{ClientError, NetClient};
use deepcot::net::proto::{ErrCode, Frame, WireError};
use deepcot::net::server::NetServer;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::Mat;
use deepcot::obs::journal::EventKind;
use deepcot::obs::ObsLevel;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn tmp_state_dir(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("deepcot-fault-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// Replay one stream's recorded tokens through an isolated 1-lane
/// scalar model and demand bit-equality with the recorded ticks.
fn assert_oracle(stream: u64, tokens: &[Vec<f32>], trace: &[TickResult]) {
    assert_eq!(tokens.len(), trace.len(), "stream {stream}: tokens vs ticks");
    let (manifest, mdir) = Manifest::load(&synth_artifacts()).unwrap();
    let entry = manifest.variant(&SyntheticServeSpec::variant_name(1)).unwrap();
    let params = ModelParams::load(&mdir, entry).unwrap();
    let mc = entry.config.clone();
    let mut oracle = BatchedScalarDeepCoT::with_lanes(mc.clone(), params, 1);
    for (t, (toks, got)) in tokens.iter().zip(trace).enumerate() {
        let lane = Mat::from_vec(1, mc.d_in, toks.clone());
        let step = oracle.tick_lanes(&lane, &[true], &[t as i32]).unwrap();
        assert_eq!(got.tick, t as u64 + 1, "stream {stream} tick {t} ordinal");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        let want_logits: Vec<u32> = step.logits.row(0).iter().map(|v| v.to_bits()).collect();
        let want_out: Vec<u32> = (0..mc.m_tokens)
            .flat_map(|r| step.out.row(r).iter().map(|v| v.to_bits()))
            .collect();
        assert_eq!(bits(&got.logits), want_logits, "stream {stream} tick {t} logits vs oracle");
        assert_eq!(bits(&got.out), want_out, "stream {stream} tick {t} out vs oracle");
    }
}

/// The fault plan flows config → engine: defaults inherit
/// `DEEPCOT_FAULT`, an explicit builder plan beats the environment.
#[test]
fn config_inherits_env_fault_plan_and_builder_overrides() {
    assert_eq!(
        EngineConfig::default().fault,
        FaultPlan::default_from_env(),
        "the default config must carry exactly the environment's plan"
    );
    let pinned: FaultPlan = "seed=3,store_put=7".parse().unwrap();
    let cfg = EngineConfig::builder().fault(pinned.clone()).build();
    assert_eq!(cfg.fault, pinned, "an explicit plan must beat DEEPCOT_FAULT");
    let off = EngineConfig::builder().fault(FaultPlan::disabled()).build();
    assert!(!off.fault.is_enabled());
}

/// `ShardFailed` over the wire: code 10, aux carries the retryable
/// flag, and the decoded client-side error is the same variant.
#[test]
fn shard_failed_survives_the_wire_byte_exactly() {
    for retryable in [true, false] {
        let e = EngineError::ShardFailed { retryable };
        let w = WireError::from_engine(3, &e);
        assert_eq!(w.code, ErrCode::ShardFailed);
        assert_eq!(w.aux, u32::from(retryable));
        let enc = Frame::Error(w).encode();
        let Frame::Error(back) = Frame::decode(&enc[4..]).unwrap() else {
            panic!("not an error frame");
        };
        assert_eq!(back.to_engine(), e, "retryable={retryable} must round-trip");
    }
}

/// One logical stream of the crash test: its session (absent while the
/// stream waits for a resume), deterministic token source, the full
/// token history for the oracle replay, and the pushed-but-unticked
/// window a resume has to re-drive.
struct Lane {
    id: StreamId,
    sess: Option<Session>,
    rng: Rng,
    history: Vec<Vec<f32>>,
    unacked: VecDeque<Vec<f32>>,
    trace: Vec<TickResult>,
    resumed: bool,
}

/// Deliver every unacked token of `lane` and collect its tick,
/// recovering from the planned shard crash through typed errors only:
/// `ShardFailed {retryable: true}` → retry; `Hibernated` (or a dead
/// output port) → drop the zombie session, `resume`, re-drive. Any
/// other error — `ShuttingDown` above all — fails the test.
fn pump(h: &EngineHandle, lane: &mut Lane) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while let Some(tok) = lane.unacked.front().cloned() {
        assert!(Instant::now() < deadline, "stream {} made no progress", lane.id.0);
        let Some(sess) = &lane.sess else {
            match h.resume(lane.id) {
                Ok(sess) => {
                    lane.sess = Some(sess);
                    lane.resumed = true;
                }
                // not re-homed yet (still bound, or the orphan row is
                // not registered): the supervisor is mid-flight
                Err(EngineError::InvalidRequest(_))
                | Err(EngineError::StreamClosed(_))
                | Err(EngineError::ShardFailed { retryable: true }) => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => panic!("stream {}: resume failed typed-unexpectedly: {e:?}", lane.id.0),
            }
            continue;
        };
        match sess.push(tok) {
            Ok(()) => {}
            Err(EngineError::ShardFailed { retryable: true }) => {
                // dead-shard window before the re-home lands
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            Err(EngineError::Hibernated(_)) => {
                // re-homed to its checkpoint: the old session is a
                // zombie — closing through it would tear down the
                // checkpoint, so leak it instead (test-only stand-in
                // for the server's internal forget path)
                std::mem::forget(lane.sess.take().unwrap());
                continue;
            }
            Err(e) => panic!("stream {}: push failed typed-unexpectedly: {e:?}", lane.id.0),
        }
        match lane.sess.as_ref().unwrap().recv_timeout(Duration::from_secs(10)) {
            Ok(tick) => {
                assert_eq!(
                    tick.tick,
                    lane.trace.len() as u64 + 1,
                    "stream {}: tick ordinals must stay contiguous across the crash",
                    lane.id.0
                );
                lane.trace.push(tick);
                lane.unacked.pop_front();
            }
            Err(EngineError::StreamClosed(_)) => {
                // the worker died holding our output port; the token at
                // the unacked front never ticked — resume re-drives it
                std::mem::forget(lane.sess.take().unwrap());
            }
            Err(e) => panic!("stream {}: recv failed typed-unexpectedly: {e:?}", lane.id.0),
        }
    }
}

/// Property 1: the tentpole. A deterministic shard-0 panic mid-load on
/// a 2-shard cluster — survivors bitwise-unaffected, crashed streams
/// resume from their checkpoint, supervisor re-homes + respawns, new
/// opens succeed, and nothing ever reports `ShuttingDown`.
#[test]
fn shard_crash_is_isolated_and_bitwise() {
    const STREAMS: usize = 4;
    const WARM: usize = 5; // rounds before the checkpoint
    const AFTER: usize = 6; // rounds driven through + past the crash
    // round-robin: 2 streams per shard, so after WARM serial rounds
    // shard 0 has ticked exactly 2*WARM times — the next shard-0 tick
    // (the first one after the snapshot) panics
    let plan: FaultPlan = format!("seed=1,shard=0,shard_step=@{}", 2 * WARM + 1).parse().unwrap();
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(2)
        .slots_per_shard(STREAMS)
        .placement(PlacementPolicy::RoundRobin)
        .hibernate(true)
        .obs(ObsLevel::Journal)
        .fault(plan)
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();

    let mut lanes: Vec<Lane> = (0..STREAMS)
        .map(|s| {
            let sess = h.open().unwrap();
            Lane {
                id: sess.id(),
                sess: Some(sess),
                rng: Rng::new(4400 + s as u64),
                history: Vec::new(),
                unacked: VecDeque::new(),
                trace: Vec::new(),
                resumed: false,
            }
        })
        .collect();

    let round_all = |lanes: &mut Vec<Lane>| {
        for lane in lanes.iter_mut() {
            let tok = lane.rng.normal_vec(D_IN, 1.0);
            lane.history.push(tok.clone());
            lane.unacked.push_back(tok);
            pump(&h, lane);
        }
    };

    // warm up, then checkpoint every stream — the injected crash lands
    // strictly after this snapshot
    for _ in 0..WARM {
        round_all(&mut lanes);
    }
    assert_eq!(h.snapshot().unwrap(), STREAMS, "every stream must be checkpointed");

    // drive through the crash: the first post-snapshot shard-0 tick
    // panics; pump() rides the typed-error recovery for every lane
    for _ in 0..AFTER {
        round_all(&mut lanes);
    }

    // every stream finished the full schedule, crash or not, and the
    // traces are bitwise what an uninterrupted scalar oracle produces
    let resumed = lanes.iter().filter(|l| l.resumed).count();
    assert_eq!(resumed, 2, "exactly the crashed shard's streams resume");
    for lane in &lanes {
        assert_eq!(lane.trace.len(), WARM + AFTER, "stream {}", lane.id.0);
        assert_oracle(lane.id.0, &lane.history, &lane.trace);
    }

    // the supervisor respawned the worker (give it a beat) and the
    // books balance: one failure, two re-homes, zero losses
    let deadline = Instant::now() + Duration::from_secs(10);
    let m = loop {
        let m = h.metrics().unwrap();
        if m.shards_respawned >= 1 || Instant::now() >= deadline {
            break m;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert_eq!(m.shard_failures, 1);
    assert_eq!(m.streams_rehomed, 2);
    assert_eq!(m.streams_lost, 0, "checkpointed streams must never be lost");
    assert_eq!(m.shards_respawned, 1);
    assert_eq!(m.shards_dead, 0, "the respawn must clear the dead flag");

    // the full supervision arc is journaled
    let events = h.obs().journal().drain();
    let count = |k: EventKind| events.iter().filter(|e| e.kind == k).count();
    assert_eq!(count(EventKind::ShardPanic), 1);
    assert_eq!(count(EventKind::StreamRehomed), 2);
    assert_eq!(count(EventKind::StreamLost), 0);
    assert_eq!(count(EventKind::ShardRespawn), 1);

    // a healthy (respawned) cluster admits new work
    let fresh = h.open().expect("open after respawn");
    fresh.close();

    for lane in lanes {
        if let Some(sess) = lane.sess {
            sess.close();
        }
    }
    engine.shutdown().unwrap();
}

/// Property 2: ≥500 ops against a slot-starved hibernating cluster
/// whose store fails on a seeded schedule (puts, syncs, and a torn
/// on-disk log tail). Durability degrades — correctness must not: every
/// tick stays bitwise-exact, periodic snapshots still return `Ok`, and
/// a fresh engine over the battered state dir boots and recovers.
#[test]
fn chaos_store_faults_stay_bitwise_over_500_ops() {
    const STREAMS: usize = 9; // over 6 lanes: constant spill/restore churn
    const ROUNDS: usize = 60; // 9 * 60 = 540 pushes
    let dir = tmp_state_dir("chaos");
    let plan: FaultPlan = "seed=77,store_put=8,store_sync=4,torn_tail=@3".parse().unwrap();
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(3)
        .slots_per_shard(2)
        .placement(PlacementPolicy::RoundRobin)
        .state_dir(dir.clone())
        .fault(plan)
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();

    // a push may bounce off a spill whose store write failed (the shard
    // falls back to rejecting) or a restore whose read failed; both are
    // scheduled faults — retry. Anything else typed-unexpected panics.
    let tolerated = |e: &EngineError| match e {
        EngineError::Saturated { .. } => true,
        EngineError::Internal(m) => m.contains("injected fault"),
        _ => false,
    };

    // an open past lane capacity spills a victim through the faulty
    // store, so admission itself can bounce off an injected put — retry
    let open = || loop {
        match h.open() {
            Ok(sess) => return sess,
            Err(e) if tolerated(&e) => std::thread::sleep(Duration::from_millis(2)),
            Err(e) => panic!("open: unexpected error: {e:?}"),
        }
    };
    let mut sessions: Vec<(Session, Rng, Vec<Vec<f32>>, Vec<TickResult>)> = (0..STREAMS)
        .map(|s| (open(), Rng::new(9900 + s as u64), Vec::new(), Vec::new()))
        .collect();
    let mut ops = 0u64;
    for round in 0..ROUNDS {
        for (sess, rng, history, trace) in sessions.iter_mut() {
            let tok = rng.normal_vec(D_IN, 1.0);
            history.push(tok.clone());
            let deadline = Instant::now() + Duration::from_secs(30);
            loop {
                match sess.push(tok.clone()) {
                    Ok(()) => break,
                    Err(e) if tolerated(&e) => {
                        assert!(
                            Instant::now() < deadline,
                            "stream {} wedged on {e:?}",
                            sess.id().0
                        );
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => panic!("stream {}: unexpected error: {e:?}", sess.id().0),
                }
            }
            ops += 1;
            let tick = sess.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(tick.tick, trace.len() as u64 + 1);
            trace.push(tick);
        }
        // the degraded-store contract: snapshots absorb scheduled store
        // failures (warn + journal + retry) instead of erroring out
        if round % 10 == 9 {
            assert!(h.snapshot().is_ok(), "snapshot must degrade, not fail");
        }
    }
    assert!(ops >= 500, "chaos run too small: {ops} ops");
    let m = h.metrics().unwrap();
    assert!(m.streams_hibernated > 0, "churn must spill through the faulty store");
    assert!(m.streams_restored > 0, "churn must restore through the faulty store");

    for (sess, _, history, trace) in &sessions {
        assert_oracle(sess.id().0, history, trace);
    }
    for (sess, ..) in sessions {
        std::mem::forget(sess); // crash-style exit: keep the stored blobs
    }
    engine.shutdown().unwrap();

    // the battered log (torn tail included) must still boot a fresh
    // engine and yield recoverable streams
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(3)
        .slots_per_shard(2)
        .state_dir(dir.clone())
        .fault(FaultPlan::disabled())
        .build();
    let engine = EngineThread::spawn(cfg).expect("recovery over a torn log must boot");
    let h = engine.handle();
    assert!(
        !h.hibernated_streams().is_empty(),
        "540 ops with snapshots must leave recoverable checkpoints"
    );
    engine.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property 3, live half: a TCP client rides through a mid-load shard
/// crash on typed wire errors alone — `ShardFailed`/`Hibernated`/
/// `StreamClosed` → OPEN-resume → ticks continue — and the server's
/// zombie session for the crashed stream must not tear the resumed
/// stream down.
#[test]
fn wire_client_recovers_from_shard_crash_via_open_resume() {
    const STREAMS: usize = 4;
    const WARM: usize = 5;
    const AFTER: usize = 8;
    let plan: FaultPlan = format!("seed=2,shard=0,shard_step=@{}", 2 * WARM + 1).parse().unwrap();
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(2)
        .slots_per_shard(STREAMS)
        .placement(PlacementPolicy::RoundRobin)
        .hibernate(true)
        .fault(plan)
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    let ids: Vec<u64> = (0..STREAMS).map(|_| client.open().unwrap()).collect();
    let mut rngs: Vec<Rng> = (0..STREAMS).map(|s| Rng::new(5500 + s as u64)).collect();
    let mut ticks_seen = vec![0u64; STREAMS];
    for _ in 0..WARM {
        for (s, &id) in ids.iter().enumerate() {
            client.push(id, &rngs[s].normal_vec(D_IN, 1.0)).unwrap();
            let t = client.recv_tick(id).unwrap();
            ticks_seen[s] = t.tick;
        }
    }
    assert_eq!(engine.handle().snapshot().unwrap(), STREAMS);

    let mut resumes = 0u64;
    for _ in 0..AFTER {
        for (s, &id) in ids.iter().enumerate() {
            let tok = rngs[s].normal_vec(D_IN, 1.0);
            let deadline = Instant::now() + Duration::from_secs(30);
            loop {
                assert!(Instant::now() < deadline, "stream {id} wedged");
                let step = match client.push(id, &tok) {
                    Ok(()) => client.recv_tick(id).map(|t| t.tick),
                    Err(e) => Err(e),
                };
                match step {
                    Ok(tick) => {
                        // a resumed stream re-drives from its checkpoint,
                        // so ordinals may step back — never skip forward
                        assert!(
                            tick <= ticks_seen[s] + 1,
                            "stream {id}: tick {tick} skipped past {}",
                            ticks_seen[s]
                        );
                        ticks_seen[s] = tick;
                        break;
                    }
                    Err(ClientError::Engine(EngineError::ShardFailed { retryable: true }))
                    | Err(ClientError::Engine(EngineError::Timeout)) => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(ClientError::Engine(EngineError::Hibernated(_)))
                    | Err(ClientError::Engine(EngineError::StreamClosed(_))) => {
                        // resync first: the crash's terminal error may
                        // have answered the wrong request, leaving a
                        // straggler reply in flight
                        let _ = client.metrics();
                        match client.open_resume(id) {
                            Ok(got) => {
                                assert_eq!(got, id, "resume must reattach the same id");
                                resumes += 1;
                            }
                            // stale trigger (stream already live again)
                            // or the re-home is still in flight
                            Err(ClientError::Engine(_)) => {
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Err(e) => panic!("stream {id}: resume transport error: {e:?}"),
                        }
                    }
                    Err(e) => panic!("stream {id}: unexpected wire error: {e:?}"),
                }
            }
        }
    }
    assert!(resumes >= 1, "the crash must force at least one OPEN-resume");
    // every stream is live and past its checkpoint — the zombie session
    // purge on resume kept the resumed streams alive
    for (s, &id) in ids.iter().enumerate() {
        assert!(ticks_seen[s] > WARM as u64, "stream {id} never got past its checkpoint");
    }
    let m = engine.handle().metrics().unwrap();
    assert!(m.shard_failures >= 1);
    assert!(m.streams_rehomed >= 1);

    client.shutdown_server().unwrap();
    server.shutdown();
    engine.shutdown().unwrap();
}

/// PR 10 leg, read half: an injected `net_read` fault inside the poll
/// loop behaves exactly like a torn socket — the frame is dropped, the
/// connection is torn down silently (typed transport error on the
/// client, never a hang or a panic), and the executor keeps serving
/// fresh connections afterwards.
#[test]
fn injected_net_read_fault_tears_the_connection_down_silently() {
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(1)
        .slots_per_shard(4)
        .fault("seed=11,net_read=@4".parse().unwrap())
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut c = NetClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Rng::new(0xFEED);

    let s = c.open().expect("open (frame 1)");
    for i in 0..2 {
        // frames 2 and 3: served normally
        c.push(s, &rng.normal_vec(D_IN, 1.0)).unwrap_or_else(|e| panic!("push {i}: {e}"));
        c.recv_tick(s).unwrap_or_else(|e| panic!("tick {i}: {e}"));
    }
    // frame 4 fires net_read=@4: silent teardown, no reply ever comes
    match c.push(s, &rng.normal_vec(D_IN, 1.0)) {
        Err(ClientError::Disconnected) | Err(ClientError::Io(_)) => {}
        other => panic!("faulted push: want a typed transport error, got {other:?}"),
    }

    // the poll loop survived: a fresh connection serves end to end
    let mut c2 = NetClient::connect(server.local_addr()).expect("reconnect");
    c2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let s2 = c2.open().expect("open after fault");
    c2.push(s2, &rng.normal_vec(D_IN, 1.0)).expect("push after fault");
    let t = c2.recv_tick(s2).expect("tick after fault");
    assert!(t.logits.iter().all(|v| v.is_finite()));
    c2.close(s2).expect("close after fault");

    // the faulted conn was reaped, not leaked
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = server.metrics();
        if m.connections_active == 1 {
            assert_eq!(m.connections_accepted, 2);
            break;
        }
        assert!(Instant::now() < deadline, "faulted connection never reaped: {m:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.shutdown();
    engine.shutdown().unwrap();
}

/// PR 10 leg, write half: an injected `net_write` fault abandons a
/// reply halfway (half a frame on the wire, then teardown) — the
/// client's length-prefix discipline must reject the tail as a typed
/// transport error, and the executor keeps serving.
#[test]
fn injected_net_write_fault_desyncs_detectably() {
    let cfg = EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(1)
        .slots_per_shard(4)
        .fault("seed=12,net_write=@2".parse().unwrap())
        .build();
    let engine = EngineThread::spawn(cfg).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut c = NetClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Rng::new(0xBEEF);

    // server write 1: the OPENED reply, delivered whole
    let s = c.open().expect("open");
    // server write 2 fires net_write=@2: half the PUSH-OK frame, then
    // poison — the ack read must fail typed, not hang on the stump
    match c.push(s, &rng.normal_vec(D_IN, 1.0)) {
        Err(ClientError::Disconnected) | Err(ClientError::Io(_)) => {}
        other => panic!("desynced push: want a typed transport error, got {other:?}"),
    }

    // the poll loop survived the poisoned teardown
    let mut c2 = NetClient::connect(server.local_addr()).expect("reconnect");
    c2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let s2 = c2.open().expect("open after fault");
    c2.push(s2, &rng.normal_vec(D_IN, 1.0)).expect("push after fault");
    let t = c2.recv_tick(s2).expect("tick after fault");
    assert!(t.logits.iter().all(|v| v.is_finite()));
    c2.close(s2).expect("close after fault");
    server.shutdown();
    engine.shutdown().unwrap();
}
