//! Coordinator integration: the engine thread end-to-end — admission,
//! batched ticks, masked lanes, churn, backpressure, and equivalence of
//! batched vs single-stream serving — all through the public `Session`
//! API over typed `EngineError`s.
//!
//! Hermetic: a synthetic manifest + weights blob is written to a temp
//! artifacts dir, and the engine runs on the batched **scalar** slot
//! backend (plus one run through `auto` fallback) — so the whole
//! serving path is exercised with no XLA shared library and no `make
//! artifacts`. Tests that drive PJRT executables directly are gated on
//! the `pjrt` feature and the real artifacts dir.

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::{EngineError, EngineThread};
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

// The default synthetic serving geometry (small enough that a scalar
// tick is ~µs); must match `SyntheticServeSpec::default()`.
const D_IN: usize = 8;
const D_MODEL: usize = 16;
const N_CLASSES: usize = 4;

/// Write (once per process) the synthetic artifacts dir the scalar
/// backend serves from: manifest.json + weights/tiny.bin, at a fixed
/// spec-derived path (deterministic contents, tmp-then-rename writes —
/// safe under concurrent test binaries).
fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn engine_cfg(variant: &str) -> EngineConfig {
    EngineConfig::builder()
        .variant(variant)
        .artifacts_dir(synth_artifacts())
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .build()
}

#[test]
fn serves_multiple_streams_to_completion() {
    // `auto` here on purpose: PJRT init fails (stub xla / no libxla)
    // and the engine must fall back to the scalar backend by itself.
    let mut cfg = engine_cfg("serve_deepcot_b4");
    cfg.backend = EngineBackend::Auto;
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let mut clients = Vec::new();
    for s in 0..4 {
        let h = h.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(s as u64);
            let sess = h.open().unwrap();
            for t in 0..12 {
                sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
                let out = sess.recv_timeout(Duration::from_secs(20)).unwrap();
                assert_eq!(out.tick, t + 1);
                assert_eq!(out.logits.len(), N_CLASSES);
                assert!(out.logits.iter().all(|v| v.is_finite()));
                assert_eq!(out.out.len(), D_MODEL);
                assert!(out.out.iter().all(|v| v.is_finite()));
            }
            sess.close();
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let m = h.metrics().unwrap();
    assert_eq!(m.outputs, 48);
    assert_eq!(m.streams_opened, 4);
    // batching must actually batch: 48 outputs in far fewer ticks
    assert!(m.ticks < 48, "no batching happened: {} ticks", m.ticks);
    engine.shutdown().unwrap();
}

#[test]
fn admission_rejects_beyond_capacity() {
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b1")).unwrap();
    let h = engine.handle();
    let _sess = h.open().unwrap();
    let err = h.open().expect_err("second stream must be rejected on B=1");
    assert!(
        matches!(err, EngineError::Saturated { capacity: 1 }),
        "want Saturated, got {err:?}"
    );
    let m = h.metrics().unwrap();
    assert_eq!(m.admission_rejects, 1);
    engine.shutdown().unwrap();
}

#[test]
fn close_frees_slot_for_new_stream() {
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b1")).unwrap();
    let h = engine.handle();
    let sess = h.open().unwrap();
    let mut rng = Rng::new(9);
    sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    sess.recv_timeout(Duration::from_secs(20)).unwrap();
    sess.close();
    // slot must become available (close is async; retry briefly)
    let mut opened = None;
    for _ in 0..50 {
        match h.open() {
            Ok(s) => {
                opened = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let sess2 = opened.expect("slot should free after close");
    sess2.push(rng.normal_vec(D_IN, 1.0)).unwrap();
    sess2.recv_timeout(Duration::from_secs(20)).unwrap();
    engine.shutdown().unwrap();
}

/// A stream that pauses while its neighbor keeps ticking must see
/// exactly the results it would have seen serving alone — masked lanes
/// keep their memory, and lanes are isolated.
#[test]
fn paused_stream_matches_solo_serving() {
    // reference: the same stream served with no neighbor at all
    let toks: Vec<Vec<f32>> = {
        let mut rng = Rng::new(4242);
        (0..8).map(|_| rng.normal_vec(D_IN, 1.0)).collect()
    };
    // Returns (per-round logits for stream A, engine tick count). The
    // tick count detects the one nondeterminism this test must not be
    // exposed to: a >deadline scheduling stall splitting a round's two
    // pushes into separate ticks, which advances the shared position
    // clock differently from the solo run.
    let serve = |with_neighbor: bool| -> (Vec<Vec<f32>>, u64) {
        let mut cfg = engine_cfg("serve_deepcot_b4");
        cfg.batch_deadline = Duration::from_millis(250);
        let engine = EngineThread::spawn(cfg).unwrap();
        let h = engine.handle();
        let sess_a = h.open().unwrap();
        let neighbor = with_neighbor.then(|| h.open().unwrap());
        let mut rng_b = Rng::new(77);
        let mut got = Vec::new();
        for (i, t) in toks.iter().enumerate() {
            sess_a.push(t.clone()).unwrap();
            if let Some(sess_b) = &neighbor {
                if i % 2 == 0 {
                    sess_b.push(rng_b.normal_vec(D_IN, 1.0)).unwrap();
                    let _ = sess_b.recv_timeout(Duration::from_secs(20)).unwrap();
                }
            }
            got.push(sess_a.recv_timeout(Duration::from_secs(20)).unwrap().logits);
        }
        let ticks = h.metrics().unwrap().ticks;
        sess_a.close();
        if let Some(sess_b) = neighbor {
            sess_b.close();
        }
        engine.shutdown().unwrap();
        (got, ticks)
    };
    let (want, solo_ticks) = serve(false);
    assert_eq!(solo_ticks, toks.len() as u64);
    // retry if a deadline-expiry split ever happens (rare CI stall)
    let got = {
        let mut attempt = 0;
        loop {
            let (got, ticks) = serve(true);
            if ticks == toks.len() as u64 {
                break got;
            }
            attempt += 1;
            assert!(attempt < 5, "engine kept splitting rounds into partial ticks");
        }
    };
    for (t, (g, w)) in got.iter().zip(&want).enumerate() {
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            assert!(
                (a - b).abs() <= 1e-6 + 1e-6 * b.abs(),
                "tick {t} logit {i}: with neighbor {a} vs solo {b}"
            );
        }
    }
}

/// Backpressure: pushing far ahead of consumption must eventually
/// reject with the typed error rather than buffer unboundedly.
#[test]
fn backpressure_rejects_runaway_producer() {
    let mut cfg = engine_cfg("serve_deepcot_b4");
    cfg.max_queue_per_stream = 2;
    // long deadline so the batcher waits for the other (empty) slots
    cfg.batch_deadline = Duration::from_secs(5);
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let a = h.open().unwrap();
    let _b = h.open().unwrap(); // second slot, never pushes
    let mut rng = Rng::new(5);
    let mut rejected = None;
    for _ in 0..10 {
        if let Err(e) = a.push(rng.normal_vec(D_IN, 1.0)) {
            rejected = Some(e);
            break;
        }
    }
    let err = rejected.expect("queue should hit the backpressure bound");
    assert!(matches!(err, EngineError::Backpressure(_)), "want Backpressure, got {err:?}");
    engine.shutdown().unwrap();
}

/// Tests that drive PJRT executables directly (no scalar fallback) —
/// these need the real `make artifacts` output and the XLA library.
#[cfg(feature = "pjrt")]
mod pjrt_only {
    use super::*;
    use deepcot::runtime::{HostTensor, Runtime, Stepper};

    fn real_artifacts_available() -> bool {
        let ok = deepcot::artifacts_dir().join("manifest.json").exists();
        if !ok {
            eprintln!("skipping PJRT engine test: no artifacts (run `make artifacts`)");
        }
        ok
    }

    /// Batched PJRT serving must match a solo PJRT stepper.
    #[test]
    fn batched_serving_matches_single_stream() {
        if !real_artifacts_available() {
            return;
        }
        let rt = Runtime::new(&deepcot::artifacts_dir()).unwrap();
        // reference: single-stream stepper on the B=1 variant
        let v1 = rt.load("serve_deepcot_b1").unwrap();
        let cfg = v1.entry.config.clone();
        let mut reference = Stepper::new(v1).unwrap();
        let mut rng = Rng::new(4242);
        let toks: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(cfg.d_in, 1.0)).collect();
        let mut want = Vec::new();
        for t in &toks {
            let out = reference
                .tick(&HostTensor::new(vec![1, 1, cfg.d_in], t.clone()).unwrap())
                .unwrap();
            want.push(out.logits.data);
        }

        // engine on B=4 (real artifacts dir, PJRT backend) with an
        // intermittent second stream
        let ecfg = EngineConfig::builder()
            .variant("serve_deepcot_b4")
            .batch_deadline(Duration::from_millis(1))
            .backend(EngineBackend::Pjrt)
            .build();
        let engine = EngineThread::spawn(ecfg).unwrap();
        let h = engine.handle();
        let sess_a = h.open().unwrap();
        let sess_b = h.open().unwrap();
        let mut rng_b = Rng::new(77);
        let mut got = Vec::new();
        for (i, t) in toks.iter().enumerate() {
            sess_a.push(t.clone()).unwrap();
            if i % 2 == 0 {
                sess_b.push(rng_b.normal_vec(cfg.d_in, 1.0)).unwrap();
                let _ = sess_b.recv_timeout(Duration::from_secs(20)).unwrap();
            }
            got.push(sess_a.recv_timeout(Duration::from_secs(20)).unwrap().logits);
        }
        sess_a.close();
        sess_b.close();
        for (t, (g, w)) in got.iter().zip(&want).enumerate() {
            for (i, (a, b)) in g.iter().zip(w).enumerate() {
                assert!(
                    (a - b).abs() <= 2e-3 + 2e-3 * b.abs(),
                    "tick {t} logit {i}: batched {a} vs solo {b}"
                );
            }
        }
        engine.shutdown().unwrap();
    }
}
