//! Coordinator integration: the engine thread end-to-end — admission,
//! batched ticks, masked lanes, churn, backpressure, and equivalence of
//! batched vs single-stream serving.

use std::time::Duration;

use deepcot::config::EngineConfig;
use deepcot::coordinator::engine::EngineThread;
use deepcot::runtime::{HostTensor, Runtime, Stepper};
use deepcot::util::rng::Rng;

fn engine_cfg(variant: &str) -> EngineConfig {
    EngineConfig {
        variant: variant.to_string(),
        batch_deadline: Duration::from_millis(1),
        ..EngineConfig::default()
    }
}

#[test]
fn serves_multiple_streams_to_completion() {
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b4")).unwrap();
    let h = engine.handle();
    let mut clients = Vec::new();
    for s in 0..4 {
        let h = h.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(s as u64);
            let (id, rx) = h.open().unwrap();
            for t in 0..12 {
                h.push(id, rng.normal_vec(64, 1.0)).unwrap();
                let out = rx.recv_timeout(Duration::from_secs(20)).unwrap();
                assert_eq!(out.tick, t + 1);
                assert_eq!(out.logits.len(), 10);
                assert!(out.logits.iter().all(|v| v.is_finite()));
            }
            h.close(id);
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let m = h.metrics().unwrap();
    assert_eq!(m.outputs, 48);
    assert_eq!(m.streams_opened, 4);
    // batching must actually batch: 48 outputs in far fewer ticks
    assert!(m.ticks < 48, "no batching happened: {} ticks", m.ticks);
    engine.shutdown().unwrap();
}

#[test]
fn admission_rejects_beyond_capacity() {
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b1")).unwrap();
    let h = engine.handle();
    let (_id, _rx) = h.open().unwrap();
    assert!(h.open().is_err(), "second stream must be rejected on B=1");
    let m = h.metrics().unwrap();
    assert_eq!(m.admission_rejects, 1);
    engine.shutdown().unwrap();
}

#[test]
fn close_frees_slot_for_new_stream() {
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b1")).unwrap();
    let h = engine.handle();
    let (id, rx) = h.open().unwrap();
    let mut rng = Rng::new(9);
    h.push(id, rng.normal_vec(64, 1.0)).unwrap();
    rx.recv_timeout(Duration::from_secs(20)).unwrap();
    h.close(id);
    // slot must become available (close is async; retry briefly)
    let mut opened = None;
    for _ in 0..50 {
        match h.open() {
            Ok(p) => {
                opened = Some(p);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let (id2, rx2) = opened.expect("slot should free after close");
    h.push(id2, rng.normal_vec(64, 1.0)).unwrap();
    rx2.recv_timeout(Duration::from_secs(20)).unwrap();
    engine.shutdown().unwrap();
}

/// A masked lane must not advance: a stream that pauses while others
/// tick sees the same results as one served alone.
#[test]
fn batched_serving_matches_single_stream() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).unwrap();
    // reference: single-stream stepper on the B=1 variant
    let v1 = rt.load("serve_deepcot_b1").unwrap();
    let cfg = v1.entry.config.clone();
    let mut reference = Stepper::new(v1).unwrap();
    let mut rng = Rng::new(4242);
    let toks: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(cfg.d_in, 1.0)).collect();
    let mut want = Vec::new();
    for t in &toks {
        let out = reference
            .tick(&HostTensor::new(vec![1, 1, cfg.d_in], t.clone()).unwrap())
            .unwrap();
        want.push(out.logits.data);
    }

    // engine on B=4 with an intermittent second stream
    let engine = EngineThread::spawn(engine_cfg("serve_deepcot_b4")).unwrap();
    let h = engine.handle();
    let (id_a, rx_a) = h.open().unwrap();
    let (id_b, rx_b) = h.open().unwrap();
    let mut rng_b = Rng::new(77);
    let mut got = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        h.push(id_a, t.clone()).unwrap();
        if i % 2 == 0 {
            h.push(id_b, rng_b.normal_vec(cfg.d_in, 1.0)).unwrap();
            let _ = rx_b.recv_timeout(Duration::from_secs(20)).unwrap();
        }
        got.push(rx_a.recv_timeout(Duration::from_secs(20)).unwrap().logits);
    }
    h.close(id_a);
    h.close(id_b);
    // Positions differ (shared engine clock vs solo counter) only if B
    // pauses change A's tick cadence — they don't: A ticks every round.
    for (t, (g, w)) in got.iter().zip(&want).enumerate() {
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            assert!(
                (a - b).abs() <= 2e-3 + 2e-3 * b.abs(),
                "tick {t} logit {i}: batched {a} vs solo {b}"
            );
        }
    }
    engine.shutdown().unwrap();
}

/// Backpressure: pushing far ahead of consumption must eventually
/// reject rather than buffer unboundedly.
#[test]
fn backpressure_rejects_runaway_producer() {
    let mut cfg = engine_cfg("serve_deepcot_b4");
    cfg.max_queue_per_stream = 2;
    // long deadline so the batcher waits for the other (empty) slots
    cfg.batch_deadline = Duration::from_secs(5);
    let engine = EngineThread::spawn(cfg).unwrap();
    let h = engine.handle();
    let (a, _rx_a) = h.open().unwrap();
    let (_b, _rx_b) = h.open().unwrap(); // second slot, never pushes
    let mut rng = Rng::new(5);
    let mut rejected = false;
    for _ in 0..10 {
        if h.push(a, rng.normal_vec(64, 1.0)).is_err() {
            rejected = true;
            break;
        }
    }
    assert!(rejected, "queue should hit the backpressure bound");
    engine.shutdown().unwrap();
}
