//! Hermetic scalar-engine correctness: the ring-buffer/batched refactor
//! against the frozen pre-refactor stepper, continual-vs-full-window
//! equivalence, lane isolation under masking, and lane recycling.
//! Synthetic weights — no artifacts, no PJRT.

use deepcot::manifest::ModelConfig;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::encoder::{encoder_forward, ScalarDeepCoT};
use deepcot::nn::naive::NaiveScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::tensor::Mat;
use deepcot::util::rng::Rng;

fn cfg(
    n_layers: usize,
    window: usize,
    m_tokens: usize,
    activation: &str,
    norm: &str,
) -> ModelConfig {
    let mut c = ModelConfig::synthetic(16, 2, n_layers, window);
    c.m_tokens = m_tokens;
    c.activation = activation.to_string();
    c.norm = norm.to_string();
    c
}

fn assert_close(what: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        // hybrid abs+rel: unnormalized regimes (SOFT / ReZero) grow
        // activations, so reassociation drift scales with magnitude
        assert!(
            (g - w).abs() <= tol + tol * w.abs(),
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

/// The refactored ring-buffer stepper must reproduce the pre-refactor
/// flat-memory stepper: same logical attention order over a deep stack
/// and many wraparounds. Since the kernel-suite refactor the hot path
/// sums with 8-wide split accumulators (fixed order, but legitimately
/// reassociated vs the naive sequential sums), so equivalence is
/// pinned at the 1e-4-scale tolerance of the `nn::kernels` determinism
/// policy rather than the old identical-numerics 1e-6
/// (tests/kernels_equiv.rs sweeps this property across odd geometries).
#[test]
fn ring_stepper_matches_pre_refactor_naive() {
    for (activation, norm, m) in
        [("softmax", "layernorm", 1usize), ("soft", "rezero", 3), ("softmax", "rezero", 2)]
    {
        let c = cfg(6, 12, m, activation, norm);
        let params = ModelParams::synthetic(&c, &mut Rng::new(42));
        let mut naive = NaiveScalarDeepCoT::new(c.clone(), params.clone());
        let mut ring = ScalarDeepCoT::new(c.clone(), params);
        let mut rng = Rng::new(7);
        // 40 ticks of m tokens: the 12-m row memory wraps many times
        for t in 0..40 {
            let tokens = Mat::from_vec(m, c.d_in, rng.normal_vec(m * c.d_in, 1.0));
            let (nl, no) = naive.tick(&tokens).unwrap();
            let (rl, ro) = ring.tick(&tokens).unwrap();
            assert_close(
                &format!("{activation}/{norm} tick {t} logits"),
                rl,
                &nl,
                5e-4,
            );
            assert_close(&format!("{activation}/{norm} tick {t} out"), &ro.data, &no.data, 5e-4);
        }
    }
}

/// Paper §III-B.1: a 1-layer continual stepper equals a 1-layer
/// full-window recompute once the window has filled (deeper stacks are
/// the paper's controlled approximation, so exact equality is a 1-layer
/// property). Checked for softmax and SOFT attention.
#[test]
fn single_layer_continual_matches_full_window() {
    for activation in ["softmax", "soft"] {
        let c = cfg(1, 8, 1, activation, "layernorm");
        let n = c.window;
        let params = ModelParams::synthetic(&c, &mut Rng::new(3));
        let mut eng = ScalarDeepCoT::new(c.clone(), params.clone());
        let mut rng = Rng::new(11);
        let mut history: Vec<Vec<f32>> = Vec::new();
        for t in 0..(2 * n + 3) {
            let tok = rng.normal_vec(c.d_in, 1.0);
            history.push(tok.clone());
            let tokens = Mat::from_vec(1, c.d_in, tok);
            let (logits, out) = eng.tick(&tokens).unwrap();
            if t + 1 < n {
                continue; // window not yet filled: cold zeros differ by design
            }
            let mut win = Mat::zeros(n, c.d_in);
            for j in 0..n {
                win.row_mut(j).copy_from_slice(&history[t + 1 - n + j]);
            }
            let pos0 = (t + 1 - n) as i32;
            let (want_logits, want_out) = encoder_forward(&c, &params, &win, pos0).unwrap();
            assert_close(
                &format!("{activation} tick {t} logits vs full window"),
                logits,
                &want_logits,
                1e-4,
            );
            assert_close(
                &format!("{activation} tick {t} newest-token out vs full window"),
                out.row(0),
                want_out.row(n - 1),
                1e-4,
            );
        }
    }
}

/// Stacked-lane stepping must be lane-exact: every lane of a batched
/// step equals a solo single-lane stepper fed the same stream.
#[test]
fn batched_lanes_match_solo_steppers() {
    let lanes = 3;
    let c = cfg(4, 10, 1, "softmax", "layernorm");
    let params = ModelParams::synthetic(&c, &mut Rng::new(21));
    let mut batched = BatchedScalarDeepCoT::with_lanes(c.clone(), params.clone(), lanes);
    let mut solos: Vec<ScalarDeepCoT> =
        (0..lanes).map(|_| ScalarDeepCoT::new(c.clone(), params.clone())).collect();
    let mut rngs: Vec<Rng> = (0..lanes).map(|l| Rng::new(100 + l as u64)).collect();
    for t in 0..25 {
        let mut stacked = Mat::zeros(lanes, c.d_in);
        let mut lane_tokens = Vec::new();
        for (l, rng) in rngs.iter_mut().enumerate() {
            let tok = rng.normal_vec(c.d_in, 1.0);
            stacked.row_mut(l).copy_from_slice(&tok);
            lane_tokens.push(tok);
        }
        let mut want: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for (solo, tok) in solos.iter_mut().zip(&lane_tokens) {
            let t1 = Mat::from_vec(1, c.d_in, tok.clone());
            let (l, o) = solo.tick(&t1).unwrap();
            want.push((l.to_vec(), o.data.clone()));
        }
        let step = batched.tick_all(&stacked).unwrap();
        for l in 0..lanes {
            assert_close(
                &format!("tick {t} lane {l} logits"),
                step.logits.row(l),
                &want[l].0,
                1e-6,
            );
            assert_close(
                &format!("tick {t} lane {l} out"),
                step.out.row(l),
                &want[l].1,
                1e-6,
            );
        }
    }
}

/// Masked lanes are inert: a lane's outputs depend only on the ticks it
/// was live for, never on other lanes' traffic.
#[test]
fn masked_lane_is_isolated_from_other_lanes() {
    let c = cfg(3, 8, 1, "softmax", "layernorm");
    let params = ModelParams::synthetic(&c, &mut Rng::new(5));
    // M: lane 0 always live; lane 1 live on a gappy schedule.
    // R: lane 0 always masked; lane 1 on the same schedule.
    let mut m_model = BatchedScalarDeepCoT::with_lanes(c.clone(), params.clone(), 2);
    let mut r_model = BatchedScalarDeepCoT::with_lanes(c.clone(), params, 2);
    let mut rng0 = Rng::new(61);
    let mut rng1 = Rng::new(62);
    // caller-owned per-lane clocks: each lane advances only on its own
    // live ticks (lane 1's clock is identical in both models)
    let mut pos_m = [0i32; 2];
    let mut pos_r = [0i32; 2];
    for t in 0..16 {
        let lane1_live = !(3..7).contains(&t);
        let mut toks = Mat::zeros(2, c.d_in);
        toks.row_mut(0).copy_from_slice(&rng0.normal_vec(c.d_in, 1.0));
        let tok1 = rng1.normal_vec(c.d_in, 1.0);
        if lane1_live {
            toks.row_mut(1).copy_from_slice(&tok1);
        }
        let m_out = m_model.tick_lanes(&toks, &[true, lane1_live], &pos_m).unwrap();
        let m_logits1 = m_out.logits.row(1).to_vec();
        let mut r_toks = Mat::zeros(2, c.d_in);
        if lane1_live {
            r_toks.row_mut(1).copy_from_slice(&tok1);
        }
        let r_out = r_model.tick_lanes(&r_toks, &[false, lane1_live], &pos_r).unwrap();
        if lane1_live {
            assert_close(
                &format!("tick {t} lane 1 logits (busy vs idle neighbor)"),
                &m_logits1,
                r_out.logits.row(1),
                1e-6,
            );
        }
        pos_m[0] += 1;
        if lane1_live {
            pos_m[1] += 1;
            pos_r[1] += 1;
        }
    }
}

/// Releasing a slot (reset_lane) must hand the next stream a genuinely
/// cold memory while leaving other lanes warm.
#[test]
fn reset_lane_recycles_to_cold_state() {
    let c = cfg(3, 8, 1, "softmax", "layernorm");
    let params = ModelParams::synthetic(&c, &mut Rng::new(17));
    let mut warm = BatchedScalarDeepCoT::with_lanes(c.clone(), params.clone(), 2);
    let mut rng = Rng::new(71);
    for _ in 0..5 {
        let toks = Mat::from_vec(2, c.d_in, rng.normal_vec(2 * c.d_in, 1.0));
        warm.tick_all(&toks).unwrap();
    }
    warm.reset_lane(1);
    assert_eq!(warm.lane_pos(1), 0, "reset_lane must rewind the lane clock");
    assert_eq!(warm.lane_pos(0), 5, "other lanes keep their clocks");
    // fresh model: its cold lane 1 (clock at 0, empty memory) must agree
    // with the recycled lane 1 — per-lane clocks make this exact
    let mut fresh = BatchedScalarDeepCoT::with_lanes(c.clone(), params, 2);
    let toks = Mat::from_vec(2, c.d_in, rng.normal_vec(2 * c.d_in, 1.0));
    let w = warm.tick_all(&toks).unwrap();
    let w_logits: Vec<Vec<f32>> = (0..2).map(|l| w.logits.row(l).to_vec()).collect();
    let f = fresh.tick_all(&toks).unwrap();
    assert_close("recycled lane 1 vs cold lane 1", &w_logits[1], f.logits.row(1), 1e-6);
    // lane 0 kept its 5 warm ticks of memory, so it must NOT look cold
    let max_diff = w_logits[0]
        .iter()
        .zip(f.logits.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 1e-5, "warm lane 0 indistinguishable from cold ({max_diff})");
}

/// Shape/mask validation errors surface instead of corrupting state.
#[test]
fn tick_rejects_bad_shapes() {
    let c = cfg(2, 6, 1, "softmax", "layernorm");
    let params = ModelParams::synthetic(&c, &mut Rng::new(1));
    let mut b = BatchedScalarDeepCoT::with_lanes(c.clone(), params, 2);
    let good = Mat::zeros(2, c.d_in);
    assert!(b.tick_lanes(&good, &[true], &[0, 0]).is_err(), "short live mask must fail");
    assert!(b.tick_lanes(&good, &[true, true], &[0]).is_err(), "short pos slice must fail");
    let bad = Mat::zeros(3, c.d_in);
    assert!(b.tick_all(&bad).is_err(), "wrong row count must fail");
    assert!(b.tick_all(&good).is_ok());
}
