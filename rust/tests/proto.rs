//! Frame-codec property tests for `net::proto` via `util::prop`:
//! random frames round-trip exactly (length prefix consistent, typed
//! errors survive as the same `EngineError` variant), and malformed
//! input — truncations, corruptions, random byte soup — always rejects
//! cleanly with a typed `ProtoError`, never a panic. This is the
//! socket-facing safety contract: the server feeds every received
//! frame through exactly these paths.

use deepcot::coordinator::session::EngineError;
use deepcot::coordinator::slots::StreamId;
use deepcot::net::proto::{Frame, RawFrame, WireError};
use deepcot::util::prop;
use deepcot::util::rng::Rng;

fn rand_string(rng: &mut Rng) -> String {
    let n = rng.below(24);
    (0..n)
        .map(|_| match rng.below(12) {
            0 => 'é',
            1 => '中',
            2 => ' ',
            _ => (b'a' + rng.below(26) as u8) as char,
        })
        .collect()
}

/// Finite random payloads (exact-equality friendly; arbitrary bit
/// patterns incl. NaN are pinned separately below).
fn rand_f32s(rng: &mut Rng, max: usize) -> Vec<f32> {
    let n = rng.below(max + 1);
    (0..n).map(|_| rng.range_f32(-1e6, 1e6)).collect()
}

fn rand_engine_error(rng: &mut Rng) -> EngineError {
    match rng.below(9) {
        0 => EngineError::Saturated { capacity: rng.below(1 << 20) },
        1 => EngineError::StreamClosed(StreamId(rng.next_u64())),
        2 => EngineError::Backpressure(StreamId(rng.next_u64())),
        3 => EngineError::ShuttingDown,
        4 => EngineError::Timeout,
        5 => EngineError::InvalidRequest(rand_string(rng)),
        6 => EngineError::Unsupported(rand_string(rng)),
        7 => EngineError::Hibernated(StreamId(rng.next_u64())),
        _ => EngineError::Internal(rand_string(rng)),
    }
}

fn rand_frame(rng: &mut Rng) -> Frame {
    match rng.below(13) {
        0 => Frame::Open {
            resume: if rng.below(2) == 0 { None } else { Some(rng.next_u64()) },
        },
        1 => Frame::Push { stream: rng.next_u64(), tokens: rand_f32s(rng, 32) },
        2 => Frame::Close { stream: rng.next_u64() },
        3 => Frame::Metrics,
        4 => Frame::Shutdown,
        12 => Frame::MetricsProm,
        5 => Frame::Opened { stream: rng.next_u64() },
        6 => Frame::PushOk { stream: rng.next_u64() },
        7 => Frame::Closed { stream: rng.next_u64() },
        8 => Frame::Tick {
            stream: rng.next_u64(),
            tick: rng.next_u64(),
            logits: rand_f32s(rng, 16),
            out: rand_f32s(rng, 64),
        },
        9 => Frame::MetricsReport { report: rand_string(rng) },
        10 => Frame::ShutdownOk,
        _ => Frame::Error(WireError::from_engine(rng.next_u64(), &rand_engine_error(rng))),
    }
}

/// Body bytes (beyond the opcode) an opcode's fixed fields require —
/// any truncation below this must reject.
fn min_fields(frame: &Frame) -> usize {
    match frame {
        // OPEN truncated to its bare opcode is a *valid* fresh open
        // (the resume id is an optional wire-compatible extension), so
        // its floor stays 0 even when a resume id was encoded.
        Frame::Open { .. } | Frame::Metrics | Frame::MetricsProm | Frame::Shutdown => 0,
        Frame::ShutdownOk | Frame::MetricsReport { .. } => 0,
        Frame::Close { .. }
        | Frame::Opened { .. }
        | Frame::PushOk { .. }
        | Frame::Closed { .. }
        | Frame::Push { .. } => 8,
        Frame::Tick { .. } => 20,
        Frame::Error(_) => 13,
    }
}

#[test]
fn prop_frames_round_trip_with_consistent_prefix() {
    prop::check("proto-roundtrip", 400, |rng| {
        let f = rand_frame(rng);
        let enc = f.encode();
        if enc.len() < 5 {
            return Err(format!("frame encoded to {} bytes (< prefix + opcode)", enc.len()));
        }
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        if len != enc.len() - 4 {
            return Err(format!("prefix says {len}, body is {}", enc.len() - 4));
        }
        let dec = Frame::decode(&enc[4..]).map_err(|e| format!("decode failed: {e}"))?;
        if dec != f {
            return Err(format!("round trip changed the frame: {f:?} -> {dec:?}"));
        }
        // encode_into on a dirty reused buffer must produce identical bytes
        let mut buf = vec![0xAA; 7];
        f.encode_into(&mut buf);
        if buf != enc {
            return Err("encode_into(reused buffer) diverged from encode()".into());
        }
        Ok(())
    });
}

#[test]
fn prop_wire_errors_round_trip_typed() {
    prop::check("proto-error-roundtrip", 300, |rng| {
        let e = rand_engine_error(rng);
        let w = WireError::from_engine(rng.next_u64(), &e);
        let enc = Frame::Error(w).encode();
        let Ok(Frame::Error(back)) = Frame::decode(&enc[4..]) else {
            return Err("error frame did not decode as an error".into());
        };
        let got = back.to_engine();
        // every variant — including Unsupported's detail string and the
        // Hibernated/StreamClosed distinction — survives the hop exactly
        if got != e {
            return Err(format!("typed error changed over the wire: {e:?} -> {got:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_truncations_reject_cleanly() {
    prop::check("proto-truncation", 250, |rng| {
        let f = rand_frame(rng);
        let enc = f.encode();
        let body = &enc[4..];
        let min = min_fields(&f);
        for cut in 0..body.len() {
            // contract: never a panic; typed error wherever the fixed
            // fields cannot possibly be present
            let res = Frame::decode(&body[..cut]);
            let fields = cut.saturating_sub(1);
            if (cut == 0 || fields < min) && res.is_ok() {
                return Err(format!(
                    "truncation to {cut} bytes decoded Ok for {f:?} (needs {min} field bytes)"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_corrupt_and_random_bytes_never_panic() {
    prop::check("proto-corruption", 400, |rng| {
        // corrupt a valid encoding in 1..=5 random body positions
        let f = rand_frame(rng);
        let mut enc = f.encode();
        if enc.len() > 4 {
            for _ in 0..rng.range(1, 6) {
                let i = rng.range(4, enc.len());
                enc[i] ^= 1 << rng.below(8);
            }
            let _ = Frame::decode(&enc[4..]); // Ok or typed Err, never panic
        }
        // pure byte soup, uniformly random
        let n = rng.below(120);
        let soup: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = Frame::decode(&soup);
        let _ = RawFrame::parse(&soup).map(|r| r.to_frame());
        Ok(())
    });
}

/// Arbitrary bit patterns — NaN, infinities, denormals — must cross
/// the hot-path codec bit-for-bit (the wire must never perturb a
/// payload the way a float round-trip through text could).
#[test]
fn hot_path_payloads_are_bit_exact() {
    let mut rng = Rng::new(0xB17);
    for _ in 0..200 {
        let n = rng.below(32);
        let bits: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
        let tokens: Vec<f32> = bits.iter().map(|&b| f32::from_bits(b)).collect();
        let mut buf = Vec::new();
        deepcot::net::proto::write_push(&mut buf, 9, &tokens);
        let raw = RawFrame::parse(&buf[4..]).unwrap();
        let mut back = Vec::new();
        assert_eq!(raw.push_fields_into(&mut back).unwrap(), 9);
        let back_bits: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
        assert_eq!(back_bits, bits, "PUSH payload must be bit-exact");

        let mut tick_buf = Vec::new();
        deepcot::net::proto::write_tick(&mut tick_buf, 9, 3, &tokens, &tokens);
        let raw = RawFrame::parse(&tick_buf[4..]).unwrap();
        let (mut lg, mut out) = (Vec::new(), Vec::new());
        assert_eq!(raw.tick_fields_into(&mut lg, &mut out).unwrap(), (9, 3));
        assert_eq!(lg.iter().map(|v| v.to_bits()).collect::<Vec<_>>(), bits);
        assert_eq!(out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(), bits);
    }
}
