//! Integration: the Rust PJRT path must reproduce the JAX host
//! reference bit-for-bit (well, f32-tolerance-for-tolerance).
//!
//! `python/compile/aot.py` dumps, for every `tiny_*` variant, a short
//! input stream plus the logits / last-token outputs computed by the L2
//! model on the host. Here we drive the same stream through
//! `runtime::Stepper` / `runtime::WindowRunner` (zero-state cold start,
//! the shared convention) and compare.
//!
//! Gated on the `pjrt` feature: these tests execute AOT artifacts on a
//! real PJRT client, which the default (stubbed-xla) build cannot do.
#![cfg(feature = "pjrt")]

use anyhow::{Context, Result};

use deepcot::runtime::{HostTensor, Runtime, Stepper, WindowRunner};
use deepcot::util::json::Json;

const RTOL: f32 = 2e-3;
const ATOL: f32 = 2e-3;

struct Golden {
    ticks: usize,
    stream: Vec<Vec<f32>>,
    logits: Vec<Vec<f32>>,
    out_last: Vec<Vec<f32>>,
}

fn load_golden(rt: &Runtime, name: &str) -> Result<Golden> {
    let entry = rt.manifest().variant(name)?;
    let gfile = entry.golden.clone().context("variant has no golden")?;
    let text = std::fs::read_to_string(rt.artifacts_dir().join(gfile))?;
    let v = Json::parse(&text)?;
    let ticks = v.req("ticks")?.as_usize()?;
    let rows = |key: &str| -> Result<Vec<Vec<f32>>> {
        v.req(key)?.as_arr()?.iter().map(|r| r.as_f32_vec()).collect()
    };
    Ok(Golden {
        ticks,
        stream: rows("stream")?,
        logits: rows("expected_logits")?,
        out_last: rows("expected_out_last")?,
    })
}

fn assert_close(name: &str, tick: usize, what: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name} tick {tick} {what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = ATOL + RTOL * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "{name} tick {tick} {what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

fn last_token(out: &HostTensor) -> Vec<f32> {
    // out: (B, m, d) -> (B, d) newest token per lane
    let d = *out.shape.last().unwrap();
    let m = out.shape[1];
    let b = out.shape[0];
    let mut v = Vec::with_capacity(b * d);
    for lane in 0..b {
        let base = lane * m * d + (m - 1) * d;
        v.extend_from_slice(&out.data[base..base + d]);
    }
    v
}

fn check_step_variant(rt: &Runtime, name: &str) -> Result<()> {
    let variant = rt.load(name)?;
    let g = load_golden(rt, name)?;
    let cfg = variant.config().clone();
    let mut stepper = Stepper::new(variant)?;
    for t in 0..g.ticks {
        let tokens = HostTensor::new(
            vec![cfg.batch, cfg.m_tokens, cfg.d_in],
            g.stream[t].clone(),
        )?;
        let out = stepper.tick(&tokens)?;
        assert_close(name, t, "logits", &out.logits.data, &g.logits[t]);
        assert_close(name, t, "out_last", &last_token(&out.out), &g.out_last[t]);
    }
    Ok(())
}

fn check_window_variant(rt: &Runtime, name: &str) -> Result<()> {
    let variant = rt.load(name)?;
    let g = load_golden(rt, name)?;
    let cfg = variant.config().clone();
    let mut runner = WindowRunner::new(variant)?;
    for t in 0..g.ticks {
        let tokens = HostTensor::new(vec![cfg.batch, cfg.d_in], g.stream[t].clone())?;
        let out = runner.tick(&tokens)?;
        assert_close(name, t, "logits", &out.logits.data, &g.logits[t]);
        assert_close(name, t, "out_last", &last_token(&out.out), &g.out_last[t]);
    }
    Ok(())
}

fn rt() -> Runtime {
    Runtime::new(&deepcot::artifacts_dir()).expect("runtime (run `make artifacts` first)")
}

macro_rules! golden_step_test {
    ($fn_name:ident, $variant:expr) => {
        #[test]
        fn $fn_name() {
            check_step_variant(&rt(), $variant).unwrap();
        }
    };
}

macro_rules! golden_window_test {
    ($fn_name:ident, $variant:expr) => {
        #[test]
        fn $fn_name() {
            check_window_variant(&rt(), $variant).unwrap();
        }
    };
}

golden_step_test!(golden_tiny_deepcot, "tiny_deepcot");
golden_step_test!(golden_tiny_deepcot_l1, "tiny_deepcot_l1");
golden_step_test!(golden_tiny_deepcot_soft, "tiny_deepcot_soft");
golden_step_test!(golden_tiny_deepcot_m3, "tiny_deepcot_m3");
golden_step_test!(golden_tiny_cotransformer, "tiny_cotransformer");
golden_step_test!(golden_tiny_xl, "tiny_xl");
golden_window_test!(golden_tiny_encoder, "tiny_encoder");
golden_window_test!(golden_tiny_encoder_l1, "tiny_encoder_l1");
golden_window_test!(golden_tiny_encoder_soft, "tiny_encoder_soft");
golden_window_test!(golden_tiny_xl_full, "tiny_xl_full");
golden_window_test!(golden_tiny_fnet, "tiny_fnet");
golden_window_test!(golden_tiny_nystrom, "tiny_nystrom");

/// The paper's §III-B.1 property at the system level: a 1-layer DeepCoT
/// stepper and a 1-layer regular encoder (same weights) produce
/// identical last-token outputs once the window has filled.
#[test]
fn one_layer_equivalence_via_pjrt() {
    let rt = rt();
    let dc = rt.load("tiny_deepcot_l1").unwrap();
    let enc = rt.load("tiny_encoder_l1").unwrap();
    let cfg = dc.config().clone();
    let mut stepper = Stepper::new(dc).unwrap();
    let mut runner = WindowRunner::new(enc).unwrap();
    let mut rng = deepcot::util::rng::Rng::new(99);
    for t in 0..(cfg.window * 2) {
        let tok = rng.normal_vec(cfg.batch * cfg.d_in, 1.0);
        let a = stepper
            .tick(&HostTensor::new(vec![cfg.batch, 1, cfg.d_in], tok.clone()).unwrap())
            .unwrap();
        let b = runner
            .tick(&HostTensor::new(vec![cfg.batch, cfg.d_in], tok).unwrap())
            .unwrap();
        if t >= cfg.window - 1 {
            assert_close("equiv", t, "logits", &a.logits.data, &b.logits.data);
        }
    }
}
