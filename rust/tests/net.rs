//! Loopback end-to-end tests for the TCP front door: the wire must be
//! invisible. A stream served over `net::server`/`net::client` produces
//! **bitwise-identical** `TickResult`s to the same stream driven
//! through the in-process `Session` API — under steady traffic, under
//! open/close churn, across live migrations, and with concurrent
//! clients on separate connections. Error semantics survive the hop
//! typed (Saturated / Backpressure / InvalidRequest / StreamClosed /
//! ShuttingDown), a dropped connection closes its streams (the RAII
//! contract at network distance), a mid-stream server shutdown hands
//! every client a terminal error rather than a hang, and a ≥10k-frame
//! malformed-input fuzz loop never takes the server down.
//!
//! The executor rewrite added its own pins: ~1000 concurrent
//! connections served by one fixed worker pool (thread count stays
//! O(workers)), a 1k connect/close churn loop that must leave the
//! connection table and the process fd count flat (the PR 10 leak
//! regression), shared-token OPEN auth gating every frame, and the
//! per-connection stream quota.
//!
//! Hermetic: `SyntheticServeSpec::default()` artifacts on the scalar
//! backend, ephemeral loopback ports, 30s socket read timeouts so any
//! would-be hang fails loudly instead of wedging CI.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use deepcot::config::EngineConfig;
use deepcot::coordinator::engine::{EngineError, EngineHandle, EngineThread, Session};
use deepcot::coordinator::slots::StreamId;
use deepcot::net::client::{ClientError, NetClient};
use deepcot::net::poller::raise_nofile;
use deepcot::net::server::{NetConfig, NetServer};
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

const D_IN: usize = 8; // must match SyntheticServeSpec::default()

fn synth_artifacts() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| SyntheticServeSpec::default().write().unwrap()).clone()
}

fn cluster_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(deepcot::config::EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .build()
}

fn tcp_client(server: &NetServer) -> NetClient {
    let client = NetClient::connect(server.local_addr()).expect("connect");
    client.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    client
}

/// One tick as comparable bits: (ordinal, logits bits, out bits).
type TickBits = (u64, Vec<u32>, Vec<u32>);

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A transport-generic stream driver: the same schedule runs through
/// the in-process `Session` API and through a `NetClient`, so traces
/// are comparable by construction.
enum Driver {
    InProc(EngineHandle),
    Tcp(NetClient),
}

enum StreamH {
    Sess(Session),
    Wire(u64),
}

impl StreamH {
    fn id(&self) -> u64 {
        match self {
            StreamH::Sess(s) => s.id().0,
            StreamH::Wire(id) => *id,
        }
    }
}

impl Driver {
    fn open(&mut self) -> StreamH {
        match self {
            Driver::InProc(h) => StreamH::Sess(h.open().expect("open")),
            Driver::Tcp(c) => StreamH::Wire(c.open().expect("tcp open")),
        }
    }

    fn push_recv(&mut self, s: &StreamH, toks: &[f32]) -> TickBits {
        match (self, s) {
            (Driver::InProc(_), StreamH::Sess(sess)) => {
                sess.push(toks.to_vec()).expect("push");
                let r = sess.recv_timeout(Duration::from_secs(30)).expect("tick result");
                (r.tick, bits(&r.logits), bits(&r.out))
            }
            (Driver::Tcp(c), StreamH::Wire(id)) => {
                c.push(*id, toks).expect("tcp push");
                let t = c.recv_tick(*id).expect("tcp tick result");
                (t.tick, bits(&t.logits), bits(&t.out))
            }
            _ => unreachable!("stream handle belongs to the other driver"),
        }
    }

    fn close(&mut self, s: StreamH) {
        match (self, s) {
            (Driver::InProc(_), StreamH::Sess(sess)) => sess.close(),
            (Driver::Tcp(c), StreamH::Wire(id)) => {
                c.close(id).expect("tcp close");
            }
            _ => unreachable!("stream handle belongs to the other driver"),
        }
    }
}

/// Steady traffic, driven serially (one outstanding token at a time so
/// timing cannot perturb traces); `before_round` is the migration hook.
fn steady_trace<F: FnMut(usize, &[StreamH])>(
    d: &mut Driver,
    streams: usize,
    rounds: usize,
    seed: u64,
    mut before_round: F,
) -> Vec<Vec<TickBits>> {
    let hs: Vec<StreamH> = (0..streams).map(|_| d.open()).collect();
    let mut rngs: Vec<Rng> = (0..streams).map(|s| Rng::new(seed + s as u64)).collect();
    let mut traces: Vec<Vec<TickBits>> = vec![Vec::new(); streams];
    for round in 0..rounds {
        before_round(round, &hs);
        for s in 0..streams {
            let toks = rngs[s].normal_vec(D_IN, 1.0);
            traces[s].push(d.push_recv(&hs[s], &toks));
        }
    }
    for h in hs {
        d.close(h);
    }
    traces
}

/// Open/close churn (mirrors tests/cluster.rs): 6 logical streams,
/// some leave mid-run and hand their slots to successors.
fn churn_trace(d: &mut Driver) -> Vec<Vec<TickBits>> {
    const LOGICAL: usize = 6;
    let mut streams: Vec<Option<StreamH>> = (0..LOGICAL).map(|_| None).collect();
    let mut rngs: Vec<Rng> = (0..LOGICAL).map(|s| Rng::new(7000 + s as u64)).collect();
    let mut traces: Vec<Vec<TickBits>> = vec![Vec::new(); LOGICAL];
    for s in streams.iter_mut().take(4) {
        *s = Some(d.open());
    }
    for round in 0..12 {
        if round == 4 {
            for s in [1, 3] {
                d.close(streams[s].take().unwrap());
            }
            streams[4] = Some(d.open());
        }
        if round == 8 {
            d.close(streams[0].take().unwrap());
            streams[5] = Some(d.open());
        }
        for s in 0..LOGICAL {
            if let Some(handle) = &streams[s] {
                let toks = rngs[s].normal_vec(D_IN, 1.0);
                traces[s].push(d.push_recv(handle, &toks));
            }
        }
    }
    for s in streams.into_iter().flatten() {
        d.close(s);
    }
    traces
}

fn assert_traces(label: &str, a: &[Vec<TickBits>], b: &[Vec<TickBits>]) {
    assert_eq!(a.len(), b.len(), "{label}: stream count");
    for (s, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta, tb, "{label}: stream {s} trace diverges");
    }
}

/// The acceptance pin: a TCP loopback stream — including mid-run live
/// migrations on a 2-shard cluster — is bitwise-identical to the same
/// stream on an in-process 1-shard engine.
#[test]
fn tcp_loopback_is_bitwise_identical_to_in_process_steady() {
    let reference = {
        let engine = EngineThread::spawn(cluster_cfg(1, 6)).unwrap();
        let mut d = Driver::InProc(engine.handle());
        let t = steady_trace(&mut d, 6, 8, 4100, |_, _| {});
        drop(d);
        engine.shutdown().unwrap();
        t
    };
    let tcp = {
        let engine = EngineThread::spawn(cluster_cfg(2, 6)).unwrap();
        let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
        let h = engine.handle();
        let mut d = Driver::Tcp(tcp_client(&server));
        // wire stream ids are engine StreamIds, so the test migrates
        // live TCP streams through the in-process handle
        let t = steady_trace(&mut d, 6, 8, 4100, |round, hs| {
            if round == 3 {
                for i in [0, 2] {
                    let id = StreamId(hs[i].id());
                    let from = h.shard_of(id).expect("stream bound");
                    h.migrate(id, (from + 1) % 2).expect("migrate");
                }
            }
        });
        drop(d);
        let m = h.metrics().unwrap();
        assert_eq!(m.migrations_completed, 2, "both TCP-stream migrations must land");
        server.shutdown();
        engine.shutdown().unwrap();
        t
    };
    assert_traces("tcp+migration vs in-process", &reference, &tcp);
}

#[test]
fn tcp_loopback_is_bitwise_identical_under_churn() {
    let reference = {
        let engine = EngineThread::spawn(cluster_cfg(1, 4)).unwrap();
        let mut d = Driver::InProc(engine.handle());
        let t = churn_trace(&mut d);
        drop(d);
        engine.shutdown().unwrap();
        t
    };
    let tcp = {
        let engine = EngineThread::spawn(cluster_cfg(2, 3)).unwrap();
        let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
        let mut d = Driver::Tcp(tcp_client(&server));
        let t = churn_trace(&mut d);
        drop(d);
        server.shutdown();
        engine.shutdown().unwrap();
        t
    };
    assert_traces("churn: tcp vs in-process", &reference, &tcp);
}

/// Concurrent clients on separate connections: stream outputs depend
/// only on the stream's own history, so every client's trace must
/// match the serial in-process reference for its seed — even with 6
/// connections racing over 3 shards.
#[test]
fn concurrent_tcp_clients_match_serial_in_process_traces() {
    const STREAMS: usize = 6;
    const ROUNDS: usize = 10;
    let reference = {
        let engine = EngineThread::spawn(cluster_cfg(1, STREAMS)).unwrap();
        let mut d = Driver::InProc(engine.handle());
        let t = steady_trace(&mut d, STREAMS, ROUNDS, 9100, |_, _| {});
        drop(d);
        engine.shutdown().unwrap();
        t
    };
    let engine = EngineThread::spawn(cluster_cfg(3, 2)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let addr = server.local_addr();
    let mut clients = Vec::new();
    for s in 0..STREAMS {
        clients.push(std::thread::spawn(move || -> Vec<TickBits> {
            let mut c = NetClient::connect(addr).expect("connect");
            c.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
            // 6 streams over 3x2 slots: an open can race a neighbor's
            // placement; retry briefly
            let stream = {
                let mut attempt = 0;
                loop {
                    match c.open() {
                        Ok(stream) => break stream,
                        Err(_) if attempt < 100 => {
                            attempt += 1;
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => panic!("tcp open: {e}"),
                    }
                }
            };
            let mut rng = Rng::new(9100 + s as u64);
            let mut trace = Vec::with_capacity(ROUNDS);
            for _ in 0..ROUNDS {
                let toks = rng.normal_vec(D_IN, 1.0);
                c.push(stream, &toks).expect("tcp push");
                let t = c.recv_tick(stream).expect("tcp tick");
                trace.push((t.tick, bits(&t.logits), bits(&t.out)));
            }
            c.close(stream).expect("tcp close");
            trace
        }));
    }
    let tcp: Vec<Vec<TickBits>> =
        clients.into_iter().map(|c| c.join().expect("client thread")).collect();
    server.shutdown();
    engine.shutdown().unwrap();
    assert_traces("concurrent tcp vs serial in-process", &reference, &tcp);
}

/// Every engine error class must arrive typed: saturation on open,
/// backpressure on an over-queued push, invalid request on a wrong
/// token width, stream-closed on an unknown id — and the metrics
/// report flows back over the wire too.
#[test]
fn error_paths_surface_typed_over_the_wire() {
    let mut cfg = cluster_cfg(1, 2);
    cfg.max_queue_per_stream = 2;
    // long deadline: with two bound streams and only one pushing, no
    // tick fires, so the starved queue fills deterministically
    cfg.batch_deadline = Duration::from_secs(5);
    let engine = EngineThread::spawn(cfg).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut client = tcp_client(&server);

    let a = client.open().expect("open a");
    let b = client.open().expect("open b");
    match client.open() {
        Err(ClientError::Engine(EngineError::Saturated { capacity })) => {
            assert_eq!(capacity, 2, "typed saturation must carry the capacity")
        }
        other => panic!("third open: want Saturated, got {other:?}"),
    }

    let mut rng = Rng::new(5);
    let toks = rng.normal_vec(D_IN, 1.0);
    for i in 0..3 {
        client.push(a, &toks).unwrap_or_else(|e| panic!("push {i} should queue: {e}"));
    }
    match client.push(a, &toks) {
        Err(ClientError::Engine(EngineError::Backpressure(id))) => assert_eq!(id.0, a),
        other => panic!("4th push: want Backpressure, got {other:?}"),
    }

    match client.push(b, &[0.0; 3]) {
        Err(ClientError::Engine(EngineError::InvalidRequest(m))) => {
            assert!(m.contains("8"), "message should name the lane width: {m}")
        }
        other => panic!("short push: want InvalidRequest, got {other:?}"),
    }
    match client.push(9999, &toks) {
        Err(ClientError::Engine(EngineError::StreamClosed(id))) => assert_eq!(id.0, 9999),
        other => panic!("unknown-stream push: want StreamClosed, got {other:?}"),
    }

    // closing the starved stream un-blocks the batcher: the queued
    // pushes tick through and arrive in order
    client.close(b).expect("close b");
    for want in 1..=3u64 {
        let t = client.recv_tick(a).expect("queued tick");
        assert_eq!(t.tick, want, "queued pushes must tick in order");
    }

    let report = client.metrics().expect("metrics over the wire");
    assert!(report.contains("cluster:"), "missing cluster section: {report}");
    assert!(report.contains("net:"), "missing net section: {report}");

    client.close(a).expect("close a");
    server.shutdown();
    engine.shutdown().unwrap();
}

/// Dropping a connection without CLOSE frames must still close its
/// streams (the RAII contract at network distance): the slot frees and
/// the engine counts a close, not a leak.
#[test]
fn client_disconnect_closes_its_streams() {
    let engine = EngineThread::spawn(cluster_cfg(1, 1)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    {
        let mut c = tcp_client(&server);
        let s = c.open().expect("open");
        let mut rng = Rng::new(11);
        c.push(s, &rng.normal_vec(D_IN, 1.0)).expect("push");
        c.recv_tick(s).expect("tick");
        // dropped here: no CLOSE frame ever sent
    }
    // teardown is async (server reader notices EOF); retry briefly
    let mut c2 = tcp_client(&server);
    let mut reopened = None;
    for _ in 0..100 {
        match c2.open() {
            Ok(s) => {
                reopened = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let s2 = reopened.expect("dropping the connection must free its slot");
    let mut rng = Rng::new(12);
    c2.push(s2, &rng.normal_vec(D_IN, 1.0)).expect("push on reopened slot");
    c2.recv_tick(s2).expect("tick on reopened slot");
    c2.close(s2).expect("close");
    let m = engine.handle().metrics().unwrap();
    assert_eq!(m.streams_opened, 2);
    assert_eq!(m.streams_closed, 2, "disconnect must register as a close");
    server.shutdown();
    engine.shutdown().unwrap();
}

/// Mid-stream server shutdown: clients get terminal errors (typed
/// ShuttingDown when the announcement wins the race, at worst a clean
/// disconnect), never a hang — the socket read timeout turns any hang
/// into a loud failure.
#[test]
fn server_shutdown_mid_stream_gives_terminal_errors_not_hangs() {
    let engine = EngineThread::spawn(cluster_cfg(2, 2)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut client = tcp_client(&server);
    let s = client.open().expect("open");
    let mut rng = Rng::new(21);
    for _ in 0..3 {
        client.push(s, &rng.normal_vec(D_IN, 1.0)).expect("push");
        client.recv_tick(s).expect("tick");
    }
    server.shutdown();
    let err = client.recv_tick(s).expect_err("recv after shutdown must fail");
    assert!(
        matches!(
            err,
            ClientError::Engine(EngineError::ShuttingDown)
                | ClientError::Engine(EngineError::StreamClosed(_))
                | ClientError::Disconnected
        ),
        "want a terminal error, got {err:?}"
    );
    let err = client.push(s, &rng.normal_vec(D_IN, 1.0)).expect_err("push after shutdown");
    assert!(
        !matches!(err, ClientError::Engine(EngineError::Timeout)),
        "push must fail terminally, not time out: {err:?}"
    );
    engine.shutdown().unwrap();
}

fn hib_cfg(shards: usize, slots_per_shard: usize) -> EngineConfig {
    EngineConfig::builder()
        .variant(SyntheticServeSpec::variant_name(1))
        .artifacts_dir(synth_artifacts())
        .backend(deepcot::config::EngineBackend::Scalar)
        .batch_deadline(Duration::from_millis(1))
        .shards(shards)
        .slots_per_shard(slots_per_shard)
        .hibernate(true)
        .build()
}

/// Hibernation stays bitwise-invisible at network distance: 6 TCP
/// streams multiplexed over 4 lanes (every round trips spill/restore
/// cycles through the state store) match the roomy in-process
/// reference exactly.
#[test]
fn hibernating_tcp_streams_are_bitwise_identical_to_in_process() {
    let reference = {
        let engine = EngineThread::spawn(cluster_cfg(1, 6)).unwrap();
        let mut d = Driver::InProc(engine.handle());
        let t = steady_trace(&mut d, 6, 8, 4300, |_, _| {});
        drop(d);
        engine.shutdown().unwrap();
        t
    };
    let tcp = {
        let engine = EngineThread::spawn(hib_cfg(2, 2)).unwrap();
        let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
        let mut d = Driver::Tcp(tcp_client(&server));
        let t = steady_trace(&mut d, 6, 8, 4300, |_, _| {});
        drop(d);
        let m = engine.handle().metrics().unwrap();
        assert!(m.streams_hibernated > 0, "6 streams on 4 lanes must spill");
        assert!(m.streams_restored > 0, "round-robin pushes must restore");
        server.shutdown();
        engine.shutdown().unwrap();
        t
    };
    assert_traces("tcp+hibernation vs roomy in-process", &reference, &tcp);
}

/// The HIBERNATED wire error is its own code, distinct from
/// stream-unknown: after a crash+recover, a bare PUSH to a recovered
/// (ownerless) stream says "hibernated — resume me", an unknown id
/// still says StreamClosed, and an OPEN-resume reattaches the stream
/// so its tick series continues bitwise-identically to an
/// uninterrupted run.
#[test]
fn hibernated_wire_error_is_distinct_from_stream_closed() {
    let state_dir = {
        let mut p = std::env::temp_dir();
        p.push(format!("deepcot-net-hib-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    };
    let disk_cfg = || {
        EngineConfig::builder()
            .variant(SyntheticServeSpec::variant_name(1))
            .artifacts_dir(synth_artifacts())
            .backend(deepcot::config::EngineBackend::Scalar)
            .batch_deadline(Duration::from_millis(1))
            .shards(1)
            .slots_per_shard(4)
            .state_dir(state_dir.clone())
            .build()
    };
    const SEED: u64 = 0xB01D;

    // uninterrupted reference: 4 ticks on a plain in-process engine
    let reference = {
        let engine = EngineThread::spawn(cluster_cfg(1, 4)).unwrap();
        let mut d = Driver::InProc(engine.handle());
        let t = steady_trace(&mut d, 1, 4, SEED, |_, _| {});
        drop(d);
        engine.shutdown().unwrap();
        t.into_iter().next().unwrap()
    };

    // phase 1: two ticks on a disk-backed engine, snapshot, then crash
    // (the session is forgotten, not closed — a close would rightly
    // delete the stored state)
    let mut rng = Rng::new(SEED);
    let mut trace: Vec<TickBits> = Vec::new();
    let id = {
        let engine = EngineThread::spawn(disk_cfg()).unwrap();
        let sess = engine.handle().open().unwrap();
        let id = sess.id().0;
        for _ in 0..2 {
            sess.push(rng.normal_vec(D_IN, 1.0)).unwrap();
            let r = sess.recv_timeout(Duration::from_secs(30)).expect("tick");
            trace.push((r.tick, bits(&r.logits), bits(&r.out)));
        }
        assert_eq!(engine.handle().snapshot().unwrap(), 1);
        std::mem::forget(sess);
        engine.shutdown().unwrap();
        id
    };

    // phase 2: recover on a fresh engine and probe the wire semantics
    let engine = EngineThread::spawn(disk_cfg()).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut client = tcp_client(&server);
    let toks = Rng::new(77).normal_vec(D_IN, 1.0);

    // a recovered stream is registered but ownerless: PUSH says
    // "hibernated", carrying the id — NOT stream-unknown
    match client.push(id, &toks) {
        Err(ClientError::Engine(EngineError::Hibernated(got))) => assert_eq!(got.0, id),
        other => panic!("push to recovered stream: want Hibernated, got {other:?}"),
    }
    // unknown ids still surface as StreamClosed, on push and on resume
    match client.push(999_999, &toks) {
        Err(ClientError::Engine(EngineError::StreamClosed(got))) => assert_eq!(got.0, 999_999),
        other => panic!("push to unknown stream: want StreamClosed, got {other:?}"),
    }
    match client.open_resume(999_999) {
        Err(ClientError::Engine(EngineError::StreamClosed(got))) => assert_eq!(got.0, 999_999),
        other => panic!("resume of unknown stream: want StreamClosed, got {other:?}"),
    }

    // OPEN-resume reattaches the stream and its history continues
    let s = client.open_resume(id).expect("resume over the wire");
    assert_eq!(s, id, "resume must hand back the recovered stream id");
    for _ in 0..2 {
        let toks = rng.normal_vec(D_IN, 1.0);
        client.push(s, &toks).expect("post-resume push");
        let t = client.recv_tick(s).expect("post-resume tick");
        trace.push((t.tick, bits(&t.logits), bits(&t.out)));
    }
    // resuming the now-live stream again is refused, typed
    match client.open_resume(id) {
        Err(ClientError::Engine(EngineError::InvalidRequest(_))) => {}
        other => panic!("second resume: want InvalidRequest, got {other:?}"),
    }
    client.close(s).expect("close");
    server.shutdown();
    engine.shutdown().unwrap();

    assert_eq!(trace, reference, "crash+recover+resume trace diverges from uninterrupted run");
    let _ = std::fs::remove_dir_all(&state_dir);
}

/// ≥10k malformed frames — valid length prefixes around random bodies
/// on one connection, plus raw byte soup on many — must never panic
/// the server; a fresh well-formed client serves normally afterwards.
#[test]
fn malformed_frame_fuzz_never_takes_the_server_down() {
    let engine = EngineThread::spawn(cluster_cfg(1, 16)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let addr = server.local_addr();
    let mut rng = Rng::new(0xF22);

    // phase 1: 10k well-framed random bodies on one connection (the
    // server must answer InvalidRequest and keep the conn); a drainer
    // thread keeps the reply direction flowing so neither side stalls
    let sock = TcpStream::connect(addr).expect("fuzz connect");
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut rsock = sock.try_clone().expect("clone");
    let drainer = std::thread::spawn(move || {
        let mut buf = [0u8; 4096];
        loop {
            match rsock.read(&mut buf) {
                Ok(0) | Err(_) => return,
                Ok(_) => {}
            }
        }
    });
    let mut wsock = sock;
    let mut frame = Vec::with_capacity(96);
    for _ in 0..10_000 {
        let len = rng.range(1, 65);
        frame.clear();
        frame.extend_from_slice(&(len as u32).to_le_bytes());
        for _ in 0..len {
            frame.push(rng.next_u64() as u8);
        }
        if wsock.write_all(&frame).is_err() {
            panic!("server dropped a connection that only sent well-framed bytes");
        }
    }
    let _ = wsock.shutdown(Shutdown::Write);
    drainer.join().expect("drainer");

    // phase 2: raw byte soup (insane length prefixes) on many
    // connections — the server tears each down without panicking
    for _ in 0..100 {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let junk: Vec<u8> = (0..64).map(|_| rng.next_u64() as u8).collect();
            let _ = s.write_all(&junk);
        }
    }

    // the server must still serve a well-formed client
    let mut c = tcp_client(&server);
    let s = c.open().expect("open after fuzz");
    let toks = rng.normal_vec(D_IN, 1.0);
    c.push(s, &toks).expect("push after fuzz");
    let t = c.recv_tick(s).expect("tick after fuzz");
    assert!(t.logits.iter().all(|v| v.is_finite()));
    c.close(s).expect("close after fuzz");
    let net = server.metrics();
    assert!(
        net.protocol_errors > 1000,
        "fuzz should have registered protocol errors, got {}",
        net.protocol_errors
    );
    server.shutdown();
    engine.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Executor-era pins: fanout, churn, auth, quota, pipelining
// ---------------------------------------------------------------------------

/// Thread count of this process (Linux only).
fn thread_count() -> Option<u64> {
    std::fs::read_dir("/proc/self/task").ok().map(|d| d.count() as u64)
}

/// Open file-descriptor count of this process (Linux only).
fn fd_count() -> Option<u64> {
    std::fs::read_dir("/proc/self/fd").ok().map(|d| d.count() as u64)
}

/// Connection teardown is asynchronous (the poll loop reaps on the
/// next readiness pass); poll the gauge instead of sleeping blind.
fn wait_active_zero(server: &NetServer) {
    for _ in 0..1000 {
        if server.metrics().connections_active == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!(
        "connections_active never drained to zero: {}",
        server.metrics().connections_active
    );
}

/// The c10k pin: ~1000 simultaneous loopback connections, each with a
/// live stream, served by one fixed worker pool. Thread-per-connection
/// would grow the process by ~1000 threads; the executor must stay
/// O(workers). Scales the target down gracefully when RLIMIT_NOFILE
/// cannot be raised (each connection costs two fds in-process).
#[test]
fn a_thousand_connections_share_one_worker_pool() {
    let limit = raise_nofile(8192).unwrap_or(1024);
    let target = 1000.min(((limit.saturating_sub(256)) / 2) as usize).max(64);
    let threads_before = thread_count();
    let engine = EngineThread::spawn(
        EngineConfig::builder()
            .variant(SyntheticServeSpec::variant_name(1))
            .artifacts_dir(synth_artifacts())
            .backend(deepcot::config::EngineBackend::Scalar)
            .batch_deadline(Duration::from_millis(1))
            .shards(2)
            .slots_per_shard(target.div_ceil(2) + 1)
            .placement(deepcot::config::PlacementPolicy::LeastLoaded)
            .build(),
    )
    .unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let addr = server.local_addr();

    // a handful of opener threads, NOT one per connection: the whole
    // point is that concurrency lives in the server's poll loop
    let spawners = 8usize;
    let per = target.div_ceil(spawners);
    let mut handles = Vec::new();
    for w in 0..spawners {
        let mine = per.min(target.saturating_sub(w * per));
        if mine == 0 {
            break;
        }
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xFA40 + w as u64);
            let mut fleet = Vec::with_capacity(mine);
            for i in 0..mine {
                let mut c = NetClient::connect(addr)
                    .unwrap_or_else(|e| panic!("fanout connect {w}/{i}: {e}"));
                c.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
                let mut attempt = 0;
                let s = loop {
                    match c.open() {
                        Ok(s) => break s,
                        Err(_) if attempt < 100 => {
                            attempt += 1;
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => panic!("fanout open {w}/{i}: {e}"),
                    }
                };
                c.push(s, &rng.normal_vec(D_IN, 1.0)).expect("fanout push");
                let t = c.recv_tick(s).expect("fanout tick");
                assert!(t.logits.iter().all(|v| v.is_finite()));
                fleet.push((c, s));
            }
            fleet
        }));
    }
    let fleets: Vec<_> = handles.into_iter().map(|h| h.join().expect("spawner")).collect();
    let held: usize = fleets.iter().map(|f| f.len()).sum();
    assert_eq!(held, target);

    let m = server.metrics();
    assert_eq!(m.connections_active, target as u64, "every connection must be live at once");
    assert_eq!(m.connections_accepted, target as u64);
    assert!(m.workers >= 2, "worker pool must be running, got {}", m.workers);
    if let Some(before) = threads_before {
        // slack covers this engine + pool plus sibling tests spawning
        // their own engines concurrently; thread-per-connection would
        // show up as +{target}
        let grown = thread_count().unwrap().saturating_sub(before);
        assert!(
            grown < 300,
            "{target} connections grew the process by {grown} threads — \
             the executor must keep thread count O(workers), not O(conns)"
        );
    }

    drop(fleets);
    wait_active_zero(&server);
    server.shutdown();
    engine.shutdown().unwrap();
}

/// The PR 10 leak regression: 1k connect/close cycles must leave the
/// connection table empty and the process fd count flat. Before the
/// fix, disconnected entries lingered in the registry and each cycle
/// leaked one accepted-socket fd (~1000 fds across this loop); the
/// slack below is far under that while tolerating concurrent tests
/// opening their own sockets in this process.
#[test]
fn connection_churn_keeps_conn_table_and_fds_flat() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let baseline_fds = fd_count();
    let mut rng = Rng::new(0xC1122);
    for i in 0..1000 {
        let mut c = tcp_client(&server);
        let s = c.open().unwrap_or_else(|e| panic!("churn open {i}: {e}"));
        if i % 4 == 0 {
            c.push(s, &rng.normal_vec(D_IN, 1.0)).expect("churn push");
            c.recv_tick(s).expect("churn tick");
        }
        c.close(s).unwrap_or_else(|e| panic!("churn close {i}: {e}"));
        // client drops here; the poll loop must reap the server side
    }
    wait_active_zero(&server);
    let m = server.metrics();
    assert_eq!(m.connections_accepted, 1000);
    assert_eq!(m.connections_active, 0, "disconnected conns must leave the table");
    if let Some(before) = baseline_fds {
        // sibling tests in this binary hold sockets of their own, so
        // poll until the table converges instead of pinning an instant
        // snapshot; a real leak (one fd per cycle ≈ +1000) never does
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let after = fd_count().unwrap();
            if after.saturating_sub(before) < 64 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "fd table stuck at {after} (baseline {before}) after 1k connect/close cycles"
            );
            std::thread::sleep(Duration::from_millis(100));
        }
    }
    server.shutdown();
    engine.shutdown().unwrap();
}

/// With a shared token configured, every frame — OPEN or otherwise —
/// is rejected until the connection's first OPEN carries the matching
/// token, and each rejection tears the connection down and counts.
#[test]
fn auth_token_gates_the_connection_until_a_valid_open() {
    let engine = EngineThread::spawn(cluster_cfg(1, 4)).unwrap();
    let cfg = NetConfig { auth_token: Some("s3cret".into()), ..NetConfig::default() };
    let server = NetServer::start_with("127.0.0.1:0", engine.handle(), cfg).unwrap();

    // no token at all: typed rejection naming the problem
    let mut bare = tcp_client(&server);
    match bare.open() {
        Err(ClientError::Engine(EngineError::InvalidRequest(m))) => {
            assert!(m.contains("auth"), "rejection should mention auth: {m}")
        }
        other => panic!("tokenless open: want InvalidRequest, got {other:?}"),
    }

    // wrong token: same rejection
    let mut wrong = tcp_client(&server);
    wrong.set_auth_token("password1");
    assert!(matches!(
        wrong.open(),
        Err(ClientError::Engine(EngineError::InvalidRequest(_)))
    ));

    // non-OPEN requests cannot sneak past the gate either
    let mut sneak = tcp_client(&server);
    assert!(matches!(
        sneak.metrics(),
        Err(ClientError::Engine(EngineError::InvalidRequest(_)))
    ));

    // the right token unlocks the whole connection
    let mut c = tcp_client(&server);
    c.set_auth_token("s3cret");
    let s = c.open().expect("authed open");
    let mut rng = Rng::new(0xA117);
    c.push(s, &rng.normal_vec(D_IN, 1.0)).expect("authed push");
    let t = c.recv_tick(s).expect("authed tick");
    assert!(t.logits.iter().all(|v| v.is_finite()));
    let report = c.metrics().expect("authed metrics");
    assert!(report.contains("net:"));
    c.close(s).expect("authed close");

    assert!(
        server.metrics().auth_failures >= 3,
        "every rejected request must be counted, got {}",
        server.metrics().auth_failures
    );
    server.shutdown();
    engine.shutdown().unwrap();
}

/// A server without a token keeps serving clients that volunteer one:
/// `OpenAuth` is treated as a plain OPEN for backward compatibility.
#[test]
fn unauthenticated_server_ignores_volunteered_tokens() {
    let engine = EngineThread::spawn(cluster_cfg(1, 2)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut c = tcp_client(&server);
    c.set_auth_token("nobody-checks-this");
    let s = c.open().expect("open with volunteered token");
    let mut rng = Rng::new(0xB0B);
    c.push(s, &rng.normal_vec(D_IN, 1.0)).expect("push");
    c.recv_tick(s).expect("tick");
    c.close(s).expect("close");
    assert_eq!(server.metrics().auth_failures, 0);
    server.shutdown();
    engine.shutdown().unwrap();
}

/// The per-connection stream quota is enforced independently of the
/// engine's global slot capacity, counted, and released on close.
#[test]
fn per_connection_stream_quota_is_enforced() {
    let engine = EngineThread::spawn(cluster_cfg(1, 8)).unwrap();
    let cfg = NetConfig { max_streams_per_conn: 2, ..NetConfig::default() };
    let server = NetServer::start_with("127.0.0.1:0", engine.handle(), cfg).unwrap();

    let mut c = tcp_client(&server);
    let a = c.open().expect("open 1");
    let b = c.open().expect("open 2");
    match c.open() {
        Err(ClientError::Engine(EngineError::Saturated { capacity })) => {
            assert_eq!(capacity, 2, "Saturated must carry the per-conn quota")
        }
        other => panic!("over-quota open: want Saturated, got {other:?}"),
    }

    // the quota is per connection, not global: a second conn opens fine
    let mut c2 = tcp_client(&server);
    let s2 = c2.open().expect("open on second conn");
    c2.close(s2).expect("close on second conn");

    // closing a stream returns headroom to the connection
    c.close(a).expect("close a");
    let d = c.open().expect("open after close");
    c.close(b).expect("close b");
    c.close(d).expect("close d");

    assert!(server.metrics().quota_rejected >= 1);
    server.shutdown();
    engine.shutdown().unwrap();
}

/// Pipelined pushes against the real server: acks settle FIFO and the
/// ticks come back in push order. Eight in flight matches the default
/// per-stream queue bound, so none of these can be rejected for
/// backpressure regardless of batcher timing.
#[test]
fn pipelined_pushes_round_trip_against_the_real_server() {
    let engine = EngineThread::spawn(cluster_cfg(1, 2)).unwrap();
    let server = NetServer::start("127.0.0.1:0", engine.handle()).unwrap();
    let mut c = tcp_client(&server);
    let s = c.open().expect("open");
    let mut rng = Rng::new(0xF1F0);
    for _ in 0..8 {
        c.push_nowait(s, &rng.normal_vec(D_IN, 1.0)).expect("push_nowait");
    }
    assert!(c.inflight() > 0, "push_nowait must actually pipeline");
    c.flush_acks().expect("flush_acks");
    assert_eq!(c.inflight(), 0);
    for want in 1..=8u64 {
        let t = c.recv_tick(s).expect("pipelined tick");
        assert_eq!(t.tick, want, "ticks must arrive in push order");
    }
    c.close(s).expect("close");
    server.shutdown();
    engine.shutdown().unwrap();
}
