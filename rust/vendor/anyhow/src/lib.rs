//! Minimal, offline shim of the `anyhow` API surface this workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Errors are flattened to a single human-readable string ("context:
//! cause: cause" chain), which is all the serving stack ever does with
//! them. Replace the path dependency with the crates.io `anyhow` for
//! downcasting/backtrace support — every call site is source-compatible.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error`, it deliberately
/// does NOT implement `std::error::Error` so the blanket
/// `From<E: std::error::Error>` conversion stays coherent.
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error(m.to_string())
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error(format!("{c}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut s = e.to_string();
        let mut src = e.source();
        while let Some(c) = src {
            s.push_str(": ");
            s.push_str(&c.to_string());
            src = c.source();
        }
        Error(s)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failure, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::other("inner")
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing");
        assert_eq!(r.unwrap_err().to_string(), "missing");
        let r: Result<i32> = Some(3).with_context(|| "unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        let e = anyhow!("bad value {}", x);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        fn g(ok: bool) -> Result<()> {
            ensure!(ok);
            ensure!(ok, "never");
            Ok(())
        }
        assert!(g(true).is_ok());
        assert!(g(false).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "inner");
    }
}
