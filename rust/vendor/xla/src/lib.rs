//! Stub of the `xla` crate (xla-rs bindings over xla_extension 0.5.1)
//! covering exactly the API surface `deepcot::runtime` uses.
//!
//! Purpose: let the whole workspace build, test and serve (scalar
//! backend) without the XLA shared library. Every PJRT entry point
//! returns an "unavailable" error, so `Runtime::new` fails cleanly and
//! callers fall back to the pure-Rust scalar engine.
//!
//! To run the real PJRT path, replace this crate with the actual
//! xla-rs bindings (github.com/LaurentMazare/xla-rs pinned to the
//! xla_extension 0.5.1 ABI) and build with `--features pjrt`.

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the real xla-rs bindings: replace \
     rust/vendor/xla with xla-rs (xla_extension 0.5.1) and point it at \
     the XLA shared library, then re-enable this feature"
);

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs's displayable error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "XLA/PJRT backend unavailable ({what}): built against the stub `xla` \
         crate; vendor xla-rs + libxla_extension and enable `--features pjrt` \
         for the device path, or use the scalar engine backend"
    )))
}

/// Element types uploadable to / readable from device buffers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// PJRT client handle (unconstructible in the stub).
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host-side literal (tuple-decomposable executable output).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn copy_raw_to<T: ElementType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("/nope.hlo").is_err());
    }
}
