"""AOT pipeline contracts: variant registry consistency, io_spec wiring,
weight-file layout, HLO emission for a tiny variant, and manifest
integrity — everything the Rust loader depends on.
"""

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, params as P, variants
from compile.config import ModelConfig


def test_variant_names_unique_and_parseable():
    vs = variants.all_variants()
    names = [n for n, _, _ in vs]
    assert len(names) == len(set(names))
    for _, family, cfg in vs:
        assert family in (
            "deepcot", "encoder", "cotransformer", "nystrom", "fnet", "xl", "xl_full",
        )
        assert cfg.window > cfg.m_tokens


def test_io_spec_state_wiring_points_at_f32_inputs():
    for name, family, cfg in variants.all_variants():
        ins, outs, state = aot.io_spec(cfg, family)
        for out_idx, in_idx in state.items():
            o = outs[int(out_idx)]
            i = ins[in_idx]
            assert o[1] == i[1], f"{name}: state shape mismatch {o} vs {i}"
            assert o[2] == i[2] == "f32"


def test_param_spec_matches_init():
    cfg = ModelConfig(
        d_in=8, d_model=16, n_heads=2, n_layers=2, window=6, n_classes=3, batch=1
    )
    for family in ("deepcot", "encoder", "fnet", "xl", "cotransformer"):
        spec = P.param_spec(cfg, family)
        init = P.init_params(cfg, family, seed=0)
        assert len(spec) == len(init)
        for (name, shape), arr in zip(spec, init):
            assert tuple(shape) == arr.shape, name
            assert arr.dtype == np.float32


def test_unflatten_roundtrip():
    cfg = ModelConfig(
        d_in=8, d_model=16, n_heads=2, n_layers=3, window=6, n_classes=3, batch=1
    )
    flat = P.init_params(cfg, "deepcot", seed=1)
    d = P.unflatten(cfg, "deepcot", tuple(jnp.asarray(a) for a in flat))
    assert len(d["layers"]) == 3
    np.testing.assert_array_equal(np.asarray(d["w_in"]), flat[0])
    assert "wq" in d["layers"][0] and "a1" not in d["layers"][0]


def test_rezero_spec_for_soft_variant():
    cfg = ModelConfig(
        d_in=8, d_model=16, n_heads=2, n_layers=2, window=6, n_classes=3,
        batch=1,
    ).soft_paper_variant()
    names = [n for n, _ in P.param_spec(cfg, "deepcot")]
    assert "l0.a1" in names and "l0.g1" not in names


def test_spec_key_dedup_is_window_invariant():
    mk = lambda w: ModelConfig(
        d_in=8, d_model=16, n_heads=2, n_layers=2, window=w, n_classes=3, batch=1
    )
    assert aot.spec_key(mk(6), "deepcot", 0) == aot.spec_key(mk(12), "deepcot", 0)
    assert aot.spec_key(mk(6), "deepcot", 0) != aot.spec_key(mk(6), "deepcot", 1)
    # xl has extra params -> different key
    assert aot.spec_key(mk(6), "deepcot", 0) != aot.spec_key(mk(6), "xl", 0)


def test_build_tiny_end_to_end(tmp_path):
    """Full aot.build for one prefix into a temp dir: manifest + hlo +
    weights + golden must exist and be mutually consistent."""
    aot.build(tmp_path, only="tiny_deepcot_l1")
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert "tiny_deepcot_l1" in man["variants"]
    e = man["variants"]["tiny_deepcot_l1"]
    hlo = (tmp_path / e["hlo"]).read_text()
    assert "HloModule" in hlo
    w = (tmp_path / e["weights"]).read_bytes()
    total = sum(int(np.prod(p["shape"])) for p in e["params"])
    assert len(w) == total * 4
    g = json.loads((tmp_path / e["golden"]).read_text())
    assert g["ticks"] == len(g["expected_logits"])
    # input shapes recorded = executable arg order
    assert [i["name"] for i in e["inputs"]] == ["tokens", "pos", "kmem", "vmem"]


def test_manifest_on_disk_is_fresh():
    """Guard against stale artifacts: every registered variant appears in
    the committed manifest (run `make artifacts` when this fails)."""
    path = pathlib.Path(__file__).resolve().parents[2] / "artifacts/manifest.json"
    if not path.exists():
        pytest.skip("artifacts not built")
    man = json.loads(path.read_text())
    registered = {n for n, _, _ in variants.all_variants()}
    missing = registered - set(man["variants"])
    assert not missing, f"stale manifest, missing {sorted(missing)[:5]}"
