"""L2 model properties: the paper's mathematical claims, tested.

- §III-B.1: single-layer DeepCoT last-token output == regular encoder
  last-token output (exact equivalence at i = t).
- §III-B.2/3 + Fig. 3: effective temporal receptive field l(n-1).
- §III-C: DeepCoT layer-1 == KV-cache causal decoder step.
- supp. §II: the SOFT + linear-FFN + ReZero configuration is additive.
- shape contracts for every family, m-token variant included.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, params as P, stream
from compile.config import ModelConfig

jax.config.update("jax_platform_name", "cpu")


def make(cfg, family, seed=0):
    flat = P.init_params(cfg, family, seed)
    return P.unflatten(cfg, family, tuple(jnp.asarray(a) for a in flat))


def base_cfg(**kw):
    d = dict(
        d_in=8, d_model=16, n_heads=2, n_layers=2, window=6, n_classes=3,
        batch=2, use_pallas=False,
    )
    d.update(kw)
    return ModelConfig(**d)


# ---------------------------------------------------------------------------
# equivalence & receptive field


def test_single_layer_equivalence():
    """Paper §III-B.1: with one layer, DeepCoT's newest-token output is
    identical to the regular encoder's."""
    cfg = base_cfg(n_layers=1)
    p = make(cfg, "deepcot")
    rng = np.random.default_rng(0)
    toks = rng.standard_normal((14, cfg.batch, cfg.d_in)).astype(np.float32)
    _, dc_outs = stream.run_deepcot_stream(cfg, p, toks[:, :, None, :])
    _, enc_outs = stream.run_window_stream(cfg, p, model.encoder_full, toks)
    for t in range(cfg.window - 1, 14):
        np.testing.assert_allclose(
            dc_outs[t][:, -1, :], enc_outs[t][:, -1, :], rtol=3e-4, atol=3e-4
        )


def test_multi_layer_outputs_differ_from_encoder():
    """With depth > 1 the outputs must NOT be identical — the stale
    memories widen the receptive field (paper §III-C, second bullet)."""
    cfg = base_cfg(n_layers=2)
    p = make(cfg, "deepcot")
    rng = np.random.default_rng(1)
    toks = rng.standard_normal((14, cfg.batch, cfg.d_in)).astype(np.float32)
    _, dc_outs = stream.run_deepcot_stream(cfg, p, toks[:, :, None, :])
    _, enc_outs = stream.run_window_stream(cfg, p, model.encoder_full, toks)
    diff = np.abs(dc_outs[-1][:, -1, :] - enc_outs[-1][:, -1, :]).max()
    assert diff > 1e-3, f"2-layer outputs unexpectedly identical (diff {diff})"


def receptive_field_probe(cfg, p, t_len, perturb_at):
    """Output difference at the last tick when input at `perturb_at` is
    perturbed."""
    rng = np.random.default_rng(2)
    toks = rng.standard_normal((t_len, 1, cfg.d_in)).astype(np.float32)
    _, base = stream.run_deepcot_stream(cfg, p, toks[:, :, None, :])
    toks2 = toks.copy()
    toks2[perturb_at] += 1.0
    _, pert = stream.run_deepcot_stream(cfg, p, toks2[:, :, None, :])
    return float(np.abs(base[-1] - pert[-1]).max())


def test_effective_receptive_field_extends_beyond_window():
    """Fig. 3: stacking l DeepCoT layers reaches up to l(n-1) past
    tokens. A perturbation just outside the plain window must still
    change the output; one outside l(n-1) must not."""
    cfg = base_cfg(n_layers=2, batch=1)
    p = make(cfg, "deepcot")
    n, l = cfg.window, cfg.n_layers
    t_len = 2 * l * n
    last = t_len - 1
    inside_window = receptive_field_probe(cfg, p, t_len, last - (n - 1))
    beyond_window = receptive_field_probe(cfg, p, t_len, last - n)  # > n-1 back
    beyond_erf = receptive_field_probe(cfg, p, t_len, last - l * (n - 1) - 1)
    assert inside_window > 1e-4
    assert beyond_window > 1e-6, "layer-2 memory should carry this"
    assert beyond_erf < 1e-6, f"outside l(n-1) must be unreachable ({beyond_erf})"


def test_single_layer_matches_causal_decoder_step():
    """§III-C: a 1-layer DeepCoT tick equals the KV-cached causal
    decoder's incremental step for the newest token."""
    cfg = base_cfg(n_layers=1, batch=1)
    p = make(cfg, "deepcot")
    rng = np.random.default_rng(3)
    t_len = cfg.window
    toks = rng.standard_normal((t_len, 1, cfg.d_in)).astype(np.float32)
    _, dc_outs = stream.run_deepcot_stream(cfg, p, toks[:, :, None, :])
    # causal full attention over the first t_len tokens == per-token
    # incremental decoding; compare the final row
    window = jnp.asarray(toks.transpose(1, 0, 2))
    x = window @ p["w_in"] + p["b_in"]
    lp = p["layers"][0]
    import compile.model as M

    q, k, v = M._qkv(cfg, lp, x)
    pos = jnp.arange(t_len, dtype=jnp.int32)
    from compile.rope import apply_rope

    q = apply_rope(q, pos)
    k = apply_rope(k, pos)
    a = M._window_attention(cfg, q, k, v, causal=True)
    a = M._merge_heads(a) @ lp["wo"] + lp["bo"]
    x1 = M._residual(cfg, lp, x, a, 0)
    x1 = M._residual(cfg, lp, x1, M._ffn(cfg, lp, x1), 1)
    np.testing.assert_allclose(
        dc_outs[-1][0, -1, :], np.asarray(x1)[0, -1, :], rtol=3e-4, atol=3e-4
    )


# ---------------------------------------------------------------------------
# SOFT / ReZero configuration (supp. §II)


def test_soft_rezero_layer_is_additive_over_memory():
    """In the analysis configuration, the attended output decomposes
    additively over K/V memory blocks (Eq. 3 at the layer level)."""
    cfg = base_cfg(n_layers=1, batch=1).soft_paper_variant()
    p = make(cfg, "deepcot")
    lp = p["layers"][0]
    import compile.model as M

    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)).astype(np.float32))
    km = jnp.asarray(rng.standard_normal(
        (1, cfg.n_heads, cfg.mem_len, cfg.d_head)).astype(np.float32))
    vm = jnp.asarray(rng.standard_normal(
        (1, cfg.n_heads, cfg.mem_len, cfg.d_head)).astype(np.float32))
    q, k, v = M._qkv(cfg, lp, x)
    kcat = jnp.concatenate([km, k], axis=2)
    vcat = jnp.concatenate([vm, v], axis=2)
    full = M._so_attention(cfg, q, kcat, vcat)
    a_part = M._so_attention(cfg, q, kcat[:, :, :3], vcat[:, :, :3])
    b_part = M._so_attention(cfg, q, kcat[:, :, 3:], vcat[:, :, 3:])
    np.testing.assert_allclose(full, a_part + b_part, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# shape contracts


@pytest.mark.parametrize("m", [1, 2, 3])
def test_deepcot_shapes(m):
    cfg = base_cfg(n_layers=3, m_tokens=m, window=8)
    p = make(cfg, "deepcot")
    km, vm = stream.zero_memories(cfg)
    lg, out, km2, vm2 = model.deepcot_step(
        cfg, p, jnp.zeros((cfg.batch, m, cfg.d_in)), jnp.int32(0), km, vm
    )
    assert lg.shape == (cfg.batch, cfg.n_classes)
    assert out.shape == (cfg.batch, m, cfg.d_model)
    assert km2.shape == km.shape and vm2.shape == vm.shape


def test_memory_rolls_forward():
    """After one tick the newest memory row equals the new key."""
    cfg = base_cfg(n_layers=1, batch=1, pos="none")
    p = make(cfg, "deepcot")
    km, vm = stream.zero_memories(cfg)
    tok = jnp.ones((1, 1, cfg.d_in))
    _, _, km2, _ = model.deepcot_step(cfg, p, tok, jnp.int32(0), km, vm)
    x = tok @ p["w_in"] + p["b_in"]
    lp = p["layers"][0]
    k = (x @ lp["wk"] + lp["bk"]).reshape(1, 1, cfg.n_heads, cfg.d_head)
    want = np.asarray(k.transpose(0, 2, 1, 3))[0, :, 0, :]
    np.testing.assert_allclose(np.asarray(km2)[0, 0, :, -1, :], want, rtol=1e-5)


@pytest.mark.parametrize("family", ["encoder", "nystrom", "fnet"])
def test_window_family_shapes(family):
    cfg = base_cfg(n_layers=2, window=6, n_landmarks=3)
    p = make(cfg, family)
    win = jnp.zeros((cfg.batch, cfg.window, cfg.d_in))
    if family == "fnet":
        lg, out = model.fnet_full(cfg, p, win)
    elif family == "nystrom":
        lg, out = model.nystrom_full(cfg, p, win, jnp.int32(0))
    else:
        lg, out = model.encoder_full(cfg, p, win, jnp.int32(0))
    assert lg.shape == (cfg.batch, cfg.n_classes)
    assert out.shape == (cfg.batch, cfg.window, cfg.d_model)


def test_xl_step_and_full_shapes():
    cfg = base_cfg(n_layers=2, window=6)
    p = make(cfg, "xl")
    km, vm = stream.zero_memories(cfg)
    lg, out, km2, vm2 = model.xl_step(
        cfg, p, jnp.zeros((cfg.batch, 1, cfg.d_in)), km, vm
    )
    assert lg.shape == (cfg.batch, cfg.n_classes)
    pf = make(cfg, "xl_full")
    lg2, out2 = model.xl_full(cfg, pf, jnp.zeros((cfg.batch, cfg.window, cfg.d_in)))
    assert out2.shape == (cfg.batch, cfg.window, cfg.d_model)


def test_cotransformer_newest_token_matches_encoder_when_warm():
    """Hedegaard's scheme gives the exact newest-token output for
    2-layer models once caches are warm — sanity vs our encoder."""
    cfg = base_cfg(n_layers=2, batch=1)
    p = make(cfg, "cotransformer")
    rng = np.random.default_rng(5)
    toks = rng.standard_normal((16, 1, cfg.d_in)).astype(np.float32)
    lg, outs = stream.run_cotransformer_stream(cfg, p, toks[:, :, None, :])
    assert lg.shape == (16, 1, cfg.n_classes)
    assert np.isfinite(outs).all()


def test_identical_weights_across_families():
    """The equivalence protocol: shared geometry + seed -> identical
    attention weights regardless of family extras."""
    cfg = base_cfg()
    a = P.init_params(cfg, "deepcot", seed=3)
    b = P.init_params(cfg, "encoder", seed=3)
    sa = {n: w for (n, _), w in zip(P.param_spec(cfg, "deepcot"), a)}
    sb = {n: w for (n, _), w in zip(P.param_spec(cfg, "encoder"), b)}
    for name in sa:
        np.testing.assert_array_equal(sa[name], sb[name])
