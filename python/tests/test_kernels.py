"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and activation variants — the CORE correctness
signal for the compute layer (system prompt deliverable c).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fnet_mixing, ref, single_output, window_attention

jax.config.update("jax_platform_name", "cpu")

dims = st.sampled_from([2, 4, 8, 16])
rows = st.integers(min_value=1, max_value=24)
acts = st.sampled_from(["softmax", "soft"])


def rnd(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(g=st.integers(1, 6), n=rows, m=st.integers(1, 4), dh=dims, act=acts)
def test_single_output_matches_ref(g, n, m, dh, act):
    n = n + m  # memory must hold at least the new rows
    q = rnd(1, g, m, dh)
    k = rnd(2, g, n, dh)
    v = rnd(3, g, n, dh)
    got = single_output.single_output_attention(q, k, v, act)
    want = []
    for i in range(g):
        if act == "softmax":
            s = q[i] @ k[i].T / jnp.sqrt(jnp.float32(dh))
            p = ref.softmax_rows(s)
        else:
            p = ref.soft_activation(q[i], k[i], dh)
        want.append(p @ v[i])
    np.testing.assert_allclose(got, jnp.stack(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 4), n=rows, dh=dims, act=acts, causal=st.booleans())
def test_window_attention_matches_ref(g, n, dh, act, causal):
    q = rnd(4, g, n, dh)
    k = rnd(5, g, n, dh)
    v = rnd(6, g, n, dh)
    got = window_attention.window_attention(q, k, v, act, causal)
    want = jax.vmap(
        lambda qq, kk, vv: ref.window_attention(qq[None], kk[None], vv[None], act, causal)[0]
    )(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(g=st.integers(1, 3), n=st.integers(2, 20), d=dims)
def test_fnet_matches_ref(g, n, d):
    x = rnd(7, g, n, d)
    got = fnet_mixing.fnet_mixing(x)
    want = jax.vmap(ref.fnet_mixing)(x)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_fnet_matches_numpy_fft():
    """Our DFT-matmul formulation equals numpy's FFT real part."""
    x = np.asarray(rnd(8, 10, 12))
    want = np.fft.fft2(x).real
    got = np.asarray(ref.fnet_mixing(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_soft_is_additive_over_rows():
    """Paper Eq. 3: SOFT attention output decomposes over K/V row blocks
    (softmax does not) — the property enabling the continual analysis."""
    dh, n = 8, 12
    q = rnd(9, 1, dh)  # (H=1, dh)
    k = rnd(10, 1, n, dh)  # (H=1, n, dh)
    v = rnd(11, 1, n, dh)
    full = ref.single_output_attention(q, k, v, "soft")
    left = ref.single_output_attention(q, k[:, :5], v[:, :5], "soft")
    right = ref.single_output_attention(q, k[:, 5:], v[:, 5:], "soft")
    np.testing.assert_allclose(full, left + right, rtol=1e-5, atol=1e-5)
    # and the softmax activation must NOT decompose
    full_sm = ref.single_output_attention(q, k, v, "softmax")
    left_sm = ref.single_output_attention(q, k[:, :5], v[:, :5], "softmax")
    right_sm = ref.single_output_attention(q, k[:, 5:], v[:, 5:], "softmax")
    assert not np.allclose(full_sm, left_sm + right_sm, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 16), dh=dims)
def test_softmax_rows_normalized(n, dh):
    s = rnd(12, n, n)
    p = np.asarray(ref.softmax_rows(s))
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, rtol=1e-5)
    assert (p >= 0).all()


def test_nystrom_approaches_full_attention():
    """With landmarks == n the Nystrom approximation should be close to
    full softmax attention."""
    h, n, dh = 2, 16, 8
    q = rnd(13, h, n, dh) * 0.3
    k = rnd(14, h, n, dh) * 0.3
    v = rnd(15, h, n, dh)
    full = ref.window_attention(q, k, v, "softmax")
    approx = ref.nystrom_attention(q, k, v, n_landmarks=n)
    np.testing.assert_allclose(approx, full, rtol=0.15, atol=0.15)


def test_iterative_pinv_inverts():
    a = np.asarray(ref.softmax_rows(rnd(16, 2, 6, 6)))
    z = np.asarray(ref.iterative_pinv(jnp.asarray(a), 10))
    eye = np.eye(6)
    for i in range(2):
        np.testing.assert_allclose(a[i] @ z[i], eye, atol=0.05)
