"""Parameter specification, initialization, and flattening.

The Rust runtime feeds parameters to the AOT executables as a flat list
of f32 buffers; the order here is the contract. `param_spec` is the
single source of truth — the manifest embeds it verbatim and the Rust
loader asserts against it.
"""

from __future__ import annotations

import numpy as np

from .config import ModelConfig

# Families whose layers carry attention projections.
ATTN_FAMILIES = ("deepcot", "encoder", "cotransformer", "nystrom")
XL_FAMILIES = ("xl", "xl_full")


def layer_spec(cfg: ModelConfig, family: str, i: int) -> list[tuple[str, tuple[int, ...]]]:
    d, f = cfg.d_model, cfg.d_ffn
    h, dh = cfg.n_heads, cfg.d_head
    spec: list[tuple[str, tuple[int, ...]]] = []
    p = f"l{i}."
    if family in ATTN_FAMILIES or family in XL_FAMILIES:
        spec += [
            (p + "wq", (d, d)),
            (p + "bq", (d,)),
            (p + "wk", (d, d)),
            (p + "bk", (d,)),
            (p + "wv", (d, d)),
            (p + "bv", (d,)),
        ]
        if family in XL_FAMILIES:
            # TransformerXL learned biases (supp. §IV Eq. 4): u is the
            # global-content bias, vb the position bias.
            spec += [(p + "u", (h, dh)), (p + "vb", (h, dh))]
        spec += [(p + "wo", (d, d)), (p + "bo", (d,))]
    # fnet has no attention params — mixing is parameter-free.
    spec += [
        (p + "w1", (d, f)),
        (p + "b1", (f,)),
        (p + "w2", (f, d)),
        (p + "b2", (d,)),
    ]
    if cfg.norm == "layernorm":
        spec += [
            (p + "g1", (d,)),
            (p + "be1", (d,)),
            (p + "g2", (d,)),
            (p + "be2", (d,)),
        ]
    else:  # rezero: scalar gates, init 1/L per paper §IV-D
        spec += [(p + "a1", ()), (p + "a2", ())]
    return spec


def param_spec(cfg: ModelConfig, family: str) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the flattening contract."""
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("w_in", (cfg.d_in, cfg.d_model)),
        ("b_in", (cfg.d_model,)),
    ]
    for i in range(cfg.n_layers):
        spec += layer_spec(cfg, family, i)
    spec += [
        ("w_cls", (cfg.d_model, cfg.n_classes)),
        ("b_cls", (cfg.n_classes,)),
    ]
    return spec


def init_params(cfg: ModelConfig, family: str, seed: int = 0) -> list[np.ndarray]:
    """Deterministic init matching the paper's equivalence protocol:
    both continual and non-continual variants are evaluated with
    *identical* parameters, so the same (cfg-geometry, seed) always
    yields byte-identical weights regardless of family extras."""
    rng = np.random.default_rng(seed)
    out: list[np.ndarray] = []
    rezero_init = 1.0 / max(cfg.n_layers, 1)
    for name, shape in param_spec(cfg, family):
        base = name.split(".")[-1]
        if base.startswith("b") and base not in ("be1", "be2"):
            arr = np.zeros(shape, dtype=np.float32)
        elif base in ("be1", "be2"):
            arr = np.zeros(shape, dtype=np.float32)
        elif base in ("g1", "g2"):
            arr = np.ones(shape, dtype=np.float32)
        elif base in ("a1", "a2"):
            arr = np.full(shape, rezero_init, dtype=np.float32)
        elif base in ("u", "vb"):
            arr = (0.02 * rng.standard_normal(shape)).astype(np.float32)
        else:  # weight matrices: scaled Gaussian (fan-in)
            fan_in = shape[0] if len(shape) > 1 else 1
            arr = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(
                np.float32
            )
        out.append(arr)
    return out


def unflatten(cfg: ModelConfig, family: str, flat: tuple) -> dict:
    """flat tuple (trace-time) -> {"w_in":..., "layers":[{...}], ...}."""
    spec = param_spec(cfg, family)
    assert len(flat) == len(spec), (len(flat), len(spec))
    by_name = {name: arr for (name, _), arr in zip(spec, flat)}
    layers = []
    for i in range(cfg.n_layers):
        p = f"l{i}."
        layers.append(
            {k[len(p) :]: v for k, v in by_name.items() if k.startswith(p)}
        )
    return {
        "w_in": by_name["w_in"],
        "b_in": by_name["b_in"],
        "layers": layers,
        "w_cls": by_name["w_cls"],
        "b_cls": by_name["b_cls"],
    }
