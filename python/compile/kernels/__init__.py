"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles.

Import surface used by the L2 model:
  kernels.single_output.single_output_attention
  kernels.window_attention.window_attention
  kernels.fnet_mixing.fnet_mixing
  kernels.ref.*  (oracles + shared helpers: DFT matrices, Nystrom pinv)
"""

from . import fnet_mixing, ref, single_output, window_attention  # noqa: F401
