"""Pallas kernel for full sliding-window attention (baseline encoders).

The non-continual contrast case: an (n x n) score matrix per head,
recomputed on every stream tick. Grid over (batch * heads); one program
computes the whole (n, n) block. On a real TPU this is the MXU-friendly
case the paper's baselines represent; here the BlockSpec documents the
HBM<->VMEM schedule and interpret=True lowers it to plain HLO
(DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wa_kernel(q_ref, k_ref, v_ref, o_ref, *, activation: str, dh: int, causal: bool):
    q = q_ref[0]  # (n, dh)
    k = k_ref[0]  # (n, dh)
    v = v_ref[0]  # (n, dh)
    n = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    if activation == "softmax":
        s = jnp.dot(q, k.T) * scale  # (n, n)
        if causal:
            row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
            col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
            s = jnp.where(col <= row, s, -jnp.inf)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
    else:  # soft
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (n, 1)
        k2 = jnp.sum(k * k, axis=-1)[None, :]  # (1, n)
        d2 = q2 - 2.0 * jnp.dot(q, k.T) + k2
        p = jnp.exp(-d2 * (0.5 * scale))
        if causal:
            row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
            col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
            p = jnp.where(col <= row, p, 0.0)
    o_ref[0] = jnp.dot(p, v)


@functools.partial(jax.jit, static_argnames=("activation", "causal"))
def window_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    activation: str = "softmax",
    causal: bool = False,
) -> jnp.ndarray:
    """q/k/v: (G, n, dh) -> (G, n, dh), G = flattened batch*heads."""
    g, n, dh = q.shape
    kernel = functools.partial(
        _wa_kernel, activation=activation, dh=dh, causal=causal
    )
    spec = pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((g, n, dh), q.dtype),
        interpret=True,
    )(q, k, v)
