"""Pallas kernel for Single-Output continual attention (paper Eq. 1-2).

This is DeepCoT's compute hot spot: m new query tokens (m=1 in the
common case; m>1 is the m-output variant of supp. §III) attend against
the per-layer Key/Value memory concatenated with the new keys/values.
The grid iterates over (batch * heads); each program keeps its whole
(n, dh) K/V tile resident in VMEM — at the paper's largest geometry
(n=1000, dh=64, f32) that is 2 * 250 KiB per program, far under the
~16 MiB VMEM budget, so whole-memory residency is the right BlockSpec
(DESIGN.md §Hardware-Adaptation).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel body is lowered to plain HLO. Structure (not
interpreted wallclock) is what we optimize at this layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _so_kernel(q_ref, k_ref, v_ref, o_ref, *, activation: str, dh: int):
    """One program: q (m, dh) vs K/V (n, dh) for a single (batch, head)."""
    q = q_ref[0]  # (m, dh)
    k = k_ref[0]  # (n, dh)
    v = v_ref[0]  # (n, dh)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    if activation == "softmax":
        s = jnp.dot(q, k.T) * scale  # (m, n)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
    else:  # soft — unnormalized Gaussian kernel, additive over K rows
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (m, 1)
        k2 = jnp.sum(k * k, axis=-1)[None, :]  # (1, n)
        d2 = q2 - 2.0 * jnp.dot(q, k.T) + k2
        p = jnp.exp(-d2 * (0.5 * scale))
    o_ref[0] = jnp.dot(p, v)  # (m, dh)


@functools.partial(jax.jit, static_argnames=("activation",))
def single_output_attention(
    q: jnp.ndarray,
    kmem: jnp.ndarray,
    vmem: jnp.ndarray,
    activation: str = "softmax",
) -> jnp.ndarray:
    """q: (G, m, dh); kmem/vmem: (G, n, dh) -> (G, m, dh).

    G is the flattened (batch * heads) grid dimension; the L2 model
    reshapes (B, H, ...) into G before calling. kmem/vmem include the
    newest m rows (the caller concatenates memory with new k/v).
    """
    g, m, dh = q.shape
    _, n, _ = kmem.shape
    kernel = functools.partial(_so_kernel, activation=activation, dh=dh)
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, m, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m, dh), q.dtype),
        interpret=True,
    )(q, kmem, vmem)
