"""Pure-jnp correctness oracles for every Pallas kernel.

These are the single source of truth for kernel numerics: pytest sweeps
the Pallas implementations (interpret=True) against these references with
hypothesis-generated shapes and dtypes.

Conventions (all functions are per-batch-free; callers vmap):
  q        : (H, dh)        one query token, split by head
  kmem/vmem: (H, n, dh)     key/value memory, *including* the newest row
  x        : (n, d)         a full attention window
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax_rows(s: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable row softmax over the last axis."""
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def soft_activation(q: jnp.ndarray, k: jnp.ndarray, dh: int) -> jnp.ndarray:
    """SOFT attention activation (paper Eq. 4).

    rho(q, K) = exp(-(q (-) K) / (2 sqrt(d))) where (q (-) K) is the
    squared Euclidean distance between each query/key pair. No row
    normalization — that is the point: the map stays additive over K rows
    (paper Eq. 3).

    q: (..., m, dh), k: (..., n, dh) -> (..., m, n)
    """
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (..., m, 1)
    k2 = jnp.sum(k * k, axis=-1)[..., None, :]  # (..., 1, n)
    qk = jnp.einsum("...md,...nd->...mn", q, k)
    d2 = q2 - 2.0 * qk + k2
    return jnp.exp(-d2 / (2.0 * jnp.sqrt(jnp.float32(dh))))


def single_output_attention(
    q: jnp.ndarray,
    kmem: jnp.ndarray,
    vmem: jnp.ndarray,
    activation: str = "softmax",
) -> jnp.ndarray:
    """Single-Output continual attention for one token (paper Eq. 1-2).

    q: (H, dh); kmem/vmem: (H, n, dh) -> (H, dh)
    """
    h, dh = q.shape
    if activation == "softmax":
        s = jnp.einsum("hd,hnd->hn", q, kmem) / jnp.sqrt(jnp.float32(dh))
        p = softmax_rows(s)
    elif activation == "soft":
        p = soft_activation(q[:, None, :], kmem, dh)[:, 0, :]  # (H, n)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return jnp.einsum("hn,hnd->hd", p, vmem)


def window_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    activation: str = "softmax",
    causal: bool = False,
) -> jnp.ndarray:
    """Full window attention (the non-continual baseline).

    q, k, v: (H, n, dh) -> (H, n, dh)
    """
    h, n, dh = q.shape
    if activation == "softmax":
        s = jnp.einsum("hmd,hnd->hmn", q, k) / jnp.sqrt(jnp.float32(dh))
        if causal:
            mask = jnp.tril(jnp.ones((n, n), dtype=bool))
            s = jnp.where(mask[None], s, -jnp.inf)
        p = softmax_rows(s)
    elif activation == "soft":
        p = soft_activation(q, k, dh)  # (H, n, n)
        if causal:
            mask = jnp.tril(jnp.ones((n, n), dtype=bool))
            p = jnp.where(mask[None], p, 0.0)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return jnp.einsum("hmn,hnd->hmd", p, v)


def dft_matrices(n: int):
    """Real/imag parts of the unnormalized DFT matrix of size n."""
    idx = jnp.arange(n, dtype=jnp.float32)
    ang = -2.0 * jnp.pi * idx[:, None] * idx[None, :] / jnp.float32(n)
    return jnp.cos(ang), jnp.sin(ang)


def fnet_mixing(x: jnp.ndarray) -> jnp.ndarray:
    """FNet token mixing: Re(FFT_seq(FFT_hidden(x))) for real x.

    Implemented via DFT matmuls (MXU-friendly; see DESIGN.md
    §Hardware-Adaptation). x: (n, d) -> (n, d)
    """
    n, d = x.shape
    cn, sn = dft_matrices(n)
    cd, sd = dft_matrices(d)
    # hidden-dim DFT of a real signal: A + iB
    a = x @ cd.T
    b = x @ sd.T
    # seq-dim DFT of (A + iB): real part = Cn A - Sn B
    return cn @ a - sn @ b


def iterative_pinv(a: jnp.ndarray, iters: int = 6) -> jnp.ndarray:
    """Newton-Schulz iterative Moore-Penrose pseudo-inverse (per-head)."""
    # init per Nystromformer: Z0 = A^T / (max row-sum * max col-sum)
    row = jnp.max(jnp.sum(jnp.abs(a), axis=-1), axis=-1)  # (H,)
    col = jnp.max(jnp.sum(jnp.abs(a), axis=-2), axis=-1)  # (H,)
    z = jnp.swapaxes(a, -1, -2) / (row * col)[:, None, None]
    eye = jnp.eye(a.shape[-1], dtype=a.dtype)
    for _ in range(iters):
        az = a @ z
        z = 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))
    return z


def nystrom_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    n_landmarks: int,
    pinv_iters: int = 6,
) -> jnp.ndarray:
    """Nystromformer attention baseline (Xiong et al., AAAI'21).

    Landmarks are segment means. q, k, v: (H, n, dh) -> (H, n, dh).
    n must be divisible by n_landmarks.
    """
    h, n, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    seg = n // n_landmarks
    ql = jnp.mean(q.reshape(h, n_landmarks, seg, dh), axis=2)
    kl = jnp.mean(k.reshape(h, n_landmarks, seg, dh), axis=2)
    f = softmax_rows(jnp.einsum("hmd,hld->hml", q, kl) * scale)  # (H,n,L)
    a = softmax_rows(jnp.einsum("hld,hjd->hlj", ql, kl) * scale)  # (H,L,L)
    b = softmax_rows(jnp.einsum("hld,hnd->hln", ql, k) * scale)  # (H,L,n)
    z = iterative_pinv(a, pinv_iters)
    return jnp.einsum("hml,hlj,hjn,hnd->hmd", f, z, b, v)
