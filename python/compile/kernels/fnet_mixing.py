"""Pallas kernel for FNet token mixing: Re(DFT_seq(DFT_hidden(x))).

FNet's FFT is a butterfly network — a poor fit for a systolic array — so
on TPU we express the transform as DFT-matrix matmuls, which are
MXU-native. The op-count model in rust/src/flops keeps the paper's
O(n log n) accounting so the asymptotic comparison is preserved
analytically (DESIGN.md §Hardware-Adaptation).

The DFT matrices are passed in (precomputed at trace time) so they lower
into the HLO as constants shared across the grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fnet_kernel(x_ref, cn_ref, sn_ref, cd_ref, sd_ref, o_ref):
    x = x_ref[0]  # (n, d)
    cn, sn = cn_ref[...], sn_ref[...]  # (n, n)
    cd, sd = cd_ref[...], sd_ref[...]  # (d, d)
    a = jnp.dot(x, cd.T)  # Re of hidden-dim DFT
    b = jnp.dot(x, sd.T)  # Im of hidden-dim DFT
    o_ref[0] = jnp.dot(cn, a) - jnp.dot(sn, b)


@jax.jit
def fnet_mixing(x: jnp.ndarray) -> jnp.ndarray:
    """x: (G, n, d) -> (G, n, d), G = batch grid."""
    g, n, d = x.shape
    cn, sn = ref.dft_matrices(n)
    cd, sd = ref.dft_matrices(d)
    full = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _fnet_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
            full(n, n),
            full(n, n),
            full(d, d),
            full(d, d),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), x.dtype),
        interpret=True,
    )(x, cn, sn, cd, sd)
