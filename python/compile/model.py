"""Layer-2: the DeepCoT encoder family in JAX (build-time only).

Every function here is pure; continual state (per-layer K/V memories) is
threaded explicitly so the Rust coordinator owns it as device-resident
PJRT buffers. All forwards call the L1 Pallas kernels (interpret=True)
unless cfg.use_pallas is False, in which case the pure-jnp oracles are
used (same numerics; the perf pass measures which lowering executes
faster on CPU PJRT — see EXPERIMENTS.md §Perf).

Step functions (continual tick):
  deepcot_step        — the paper: L stacked Single-Output layers
  cotransformer_step  — Hedegaard baseline: retroactive L0 + SO last
  xl_step             — DeepCoT-XL continual tick (supp. §IV Eq. 4)

Window functions (non-continual baselines, recomputed each tick):
  encoder_full, nystrom_full, fnet_full, xl_full
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import fnet_mixing as _fnet
from .kernels import ref
from .kernels import single_output as _so
from .kernels import window_attention as _wa
from .rope import apply_rope

# ---------------------------------------------------------------------------
# shared sub-blocks


def _split_heads(x: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B, T, d) -> (B, H, T, dh)"""
    b, t, d = x.shape
    return x.reshape(b, t, h, d // h).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, T, dh) -> (B, T, d)"""
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _ffn(cfg: ModelConfig, lp: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ lp["w1"] + lp["b1"]
    if cfg.ffn_act == "gelu":
        h = jax.nn.gelu(h)
    return h @ lp["w2"] + lp["b2"]


def _residual(cfg: ModelConfig, lp: dict, x, sub, idx: int):
    """Post-norm residual: LayerNorm(x + sub) or ReZero x + a*sub
    (supp. §II — ReZero keeps the layer map additive over tokens)."""
    if cfg.norm == "layernorm":
        g, b = (lp["g1"], lp["be1"]) if idx == 0 else (lp["g2"], lp["be2"])
        return _layer_norm(x + sub, g, b)
    a = lp["a1"] if idx == 0 else lp["a2"]
    return x + a * sub


def _qkv(cfg: ModelConfig, lp: dict, x: jnp.ndarray):
    """(B, T, d) -> q, k, v each (B, H, T, dh)."""
    q = _split_heads(x @ lp["wq"] + lp["bq"], cfg.n_heads)
    k = _split_heads(x @ lp["wk"] + lp["bk"], cfg.n_heads)
    v = _split_heads(x @ lp["wv"] + lp["bv"], cfg.n_heads)
    return q, k, v


def _embed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w_in"] + params["b_in"]


def _readout(params: dict, last_tok: jnp.ndarray) -> jnp.ndarray:
    """Classification from the newest output token (no [CLS]; supp. §V)."""
    return last_tok @ params["w_cls"] + params["b_cls"]


# ---------------------------------------------------------------------------
# continual single-output layer (the paper's contribution)


def _so_attention(cfg: ModelConfig, q, kcat, vcat):
    """q: (B,H,m,dh); kcat/vcat: (B,H,n,dh) -> (B,H,m,dh)."""
    b, h, m, dh = q.shape
    n = kcat.shape[2]
    if cfg.use_pallas:
        out = _so.single_output_attention(
            q.reshape(b * h, m, dh),
            kcat.reshape(b * h, n, dh),
            vcat.reshape(b * h, n, dh),
            cfg.activation,
        )
        return out.reshape(b, h, m, dh)
    return _so_ref(cfg, q, kcat, vcat)


def _so_ref(cfg: ModelConfig, q, kcat, vcat):
    """Pure-jnp single-output attention (m query rows vs n K/V rows)."""
    dh = q.shape[-1]
    if cfg.activation == "softmax":
        s = jnp.einsum("bhmd,bhnd->bhmn", q, kcat) / jnp.sqrt(jnp.float32(dh))
        p = ref.softmax_rows(s)
    else:
        p = ref.soft_activation(q, kcat, dh)
    return jnp.einsum("bhmn,bhnd->bhmd", p, vcat)


def _deepcot_layer(cfg: ModelConfig, lp: dict, x, kmem, vmem, pos):
    """One continual layer tick.

    x: (B, m, d) new tokens; kmem/vmem: (B, H, M, dh), M = n - m.
    Returns (y (B,m,d), kmem', vmem').
    """
    m = x.shape[1]
    q, k, v = _qkv(cfg, lp, x)
    if cfg.pos == "rope":
        newpos = pos + jnp.arange(m, dtype=jnp.int32)
        q = apply_rope(q, newpos)
        k = apply_rope(k, newpos)
    kcat = jnp.concatenate([kmem, k], axis=2)  # (B,H,n,dh)
    vcat = jnp.concatenate([vmem, v], axis=2)
    a = _so_attention(cfg, q, kcat, vcat)
    a = _merge_heads(a) @ lp["wo"] + lp["bo"]
    x = _residual(cfg, lp, x, a, 0)
    x = _residual(cfg, lp, x, _ffn(cfg, lp, x), 1)
    # roll: drop the oldest m rows, keep the newest M
    return x, kcat[:, :, m:, :], vcat[:, :, m:, :]


def deepcot_step(cfg: ModelConfig, params: dict, tokens, pos, kmem, vmem):
    """The DeepCoT continual tick (paper §III-A).

    tokens: (B, m, d_in); pos: () int32 — absolute stream position of the
    first new token; kmem/vmem: (L, B, H, M, dh).
    Returns (logits (B, C), out (B, m, d), kmem', vmem').
    """
    x = _embed(params, tokens)
    new_k, new_v = [], []
    for i, lp in enumerate(params["layers"]):
        x, k_i, v_i = _deepcot_layer(cfg, lp, x, kmem[i], vmem[i], pos)
        new_k.append(k_i)
        new_v.append(v_i)
    logits = _readout(params, x[:, -1, :])
    return logits, x, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# non-continual window baselines


def _window_attention(cfg: ModelConfig, q, k, v, causal=False):
    b, h, n, dh = q.shape
    if cfg.use_pallas:
        out = _wa.window_attention(
            q.reshape(b * h, n, dh),
            k.reshape(b * h, n, dh),
            v.reshape(b * h, n, dh),
            cfg.activation,
            causal,
        )
        return out.reshape(b, h, n, dh)
    return jax.vmap(
        lambda qq, kk, vv: ref.window_attention(qq, kk, vv, cfg.activation, causal)
    )(q, k, v)


def _encoder_layer(cfg: ModelConfig, lp: dict, x, pos, attn):
    q, k, v = _qkv(cfg, lp, x)
    if cfg.pos == "rope":
        p = pos + jnp.arange(x.shape[1], dtype=jnp.int32)
        q = apply_rope(q, p)
        k = apply_rope(k, p)
    a = attn(q, k, v)
    a = _merge_heads(a) @ lp["wo"] + lp["bo"]
    x = _residual(cfg, lp, x, a, 0)
    return _residual(cfg, lp, x, _ffn(cfg, lp, x), 1)


def encoder_full(cfg: ModelConfig, params: dict, window, pos):
    """Regular sliding-window encoder (Transformer/Roformer baseline).

    window: (B, n, d_in); pos: () int32 — absolute position of the first
    window token (so last-token outputs are comparable to deepcot_step).
    Returns (logits (B, C), out (B, n, d)).
    """
    x = _embed(params, window)
    attn = lambda q, k, v: _window_attention(cfg, q, k, v)
    for lp in params["layers"]:
        x = _encoder_layer(cfg, lp, x, pos, attn)
    return _readout(params, x[:, -1, :]), x


def nystrom_full(cfg: ModelConfig, params: dict, window, pos):
    """Nystromformer baseline — landmark-approximated window attention."""
    assert cfg.n_landmarks > 0 and cfg.window % cfg.n_landmarks == 0
    x = _embed(params, window)
    attn = lambda q, k, v: jax.vmap(
        lambda qq, kk, vv: ref.nystrom_attention(qq, kk, vv, cfg.n_landmarks)
    )(q, k, v)
    for lp in params["layers"]:
        x = _encoder_layer(cfg, lp, x, pos, attn)
    return _readout(params, x[:, -1, :]), x


def fnet_full(cfg: ModelConfig, params: dict, window):
    """FNet baseline: Fourier token mixing replaces attention (no
    positional input — the mixing itself is index-aware)."""
    x = _embed(params, window)
    for lp in params["layers"]:
        if cfg.use_pallas:
            a = _fnet.fnet_mixing(x)
        else:
            a = jax.vmap(ref.fnet_mixing)(x)
        x = _residual(cfg, lp, x, a, 0)
        x = _residual(cfg, lp, x, _ffn(cfg, lp, x), 1)
    return _readout(params, x[:, -1, :]), x


# ---------------------------------------------------------------------------
# Continual Transformer baseline (Hedegaard et al.) — 2-layer scheme:
# retroactive attention in layer 0 (cached rotated projections, all n
# outputs refreshed each tick), Single-Output in the last layer. Middle
# layers, if any, are non-continual — exactly the limitation DeepCoT
# lifts (supp. §I-C).


def cotransformer_step(cfg: ModelConfig, params: dict, token, pos, qmem, kmem, vmem):
    """token: (B, 1, d_in); qmem/kmem/vmem: (B, H, n-1, dh) — layer-0
    rotated projections of the previous n-1 window tokens.
    Returns (logits, out (B,1,d), qmem', kmem', vmem').

    Layer 0 re-attends the full window from cached projections: the
    projection work is saved, the attention product is recomputed. This
    matches the paper's observation that retroactive runtime stays near
    the non-continual baseline despite a lower FLOP count (the analytic
    FLOPs model in rust/src/flops reports Hedegaard's continual counts).
    The residual stream of cached positions is not cached (only their
    projections are), so cached rows re-enter the FFN from the attended
    value; the newest token's path — the one classification uses — is
    exact.
    """
    x = _embed(params, token)  # (B, 1, d)
    lp0 = params["layers"][0]
    q, k, v = _qkv(cfg, lp0, x)  # each (B, H, 1, dh)
    if cfg.pos == "rope":
        p = pos + jnp.arange(1, dtype=jnp.int32)
        q = apply_rope(q, p)
        k = apply_rope(k, p)
    qcat = jnp.concatenate([qmem, q], axis=2)  # (B,H,n,dh)
    kcat = jnp.concatenate([kmem, k], axis=2)
    vcat = jnp.concatenate([vmem, v], axis=2)
    a = _window_attention(cfg, qcat, kcat, vcat)  # retroactive refresh
    a = _merge_heads(a) @ lp0["wo"] + lp0["bo"]  # (B, n, d)
    # newest token keeps its residual; cached rows use attended value only
    resid = jnp.concatenate([a[:, :-1, :], x + a[:, -1:, :]], axis=1)
    if cfg.norm == "layernorm":
        xn = _layer_norm(resid, lp0["g1"], lp0["be1"])
    else:
        xn = resid
    xn = _residual(cfg, lp0, xn, _ffn(cfg, lp0, xn), 1)
    # middle layers: plain non-continual encoder layers over the window
    wpos = pos - jnp.int32(cfg.window - 1)
    for lp in params["layers"][1:-1]:
        xn = _encoder_layer(
            cfg, lp, xn, wpos, lambda q_, k_, v_: _window_attention(cfg, q_, k_, v_)
        )
    # last layer: single-output for the newest token
    lpl = params["layers"][-1]
    ql, kl, vl = _qkv(cfg, lpl, xn)
    if cfg.pos == "rope":
        pw = wpos + jnp.arange(cfg.window, dtype=jnp.int32)
        ql = apply_rope(ql, pw)
        kl = apply_rope(kl, pw)
    al = _so_attention(cfg, ql[:, :, -1:, :], kl, vl)  # (B,H,1,dh)
    al = _merge_heads(al) @ lpl["wo"] + lpl["bo"]
    y = _residual(cfg, lpl, xn[:, -1:, :], al, 0)
    y = _residual(cfg, lpl, y, _ffn(cfg, lpl, y), 1)
    return (
        _readout(params, y[:, -1, :]),
        y,
        qcat[:, :, 1:, :],
        kcat[:, :, 1:, :],
        vcat[:, :, 1:, :],
    )


# ---------------------------------------------------------------------------
# DeepCoT-XL (supp. §IV Eq. 4): TransformerXL attention with continual
# K/V memories. alpha_XL = softmax((q_u K^T + q_v P) * scale) V.


def _xl_pos_matrix(n: int, dh: int) -> jnp.ndarray:
    """Sinusoidal relative-position matrix P: (n, dh); row j embeds the
    relative lag (n-1-j), so the newest K row has lag 0."""
    lag = jnp.arange(n - 1, -1, -1, dtype=jnp.float32)  # (n,)
    half = dh // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / dh))
    ang = lag[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (n, dh)


def _xl_attention(cfg: ModelConfig, lp: dict, q, kcat, vcat):
    """q: (B,H,m,dh); kcat/vcat: (B,H,n,dh)."""
    dh = q.shape[-1]
    n = kcat.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    p = _xl_pos_matrix(n, dh)  # (n, dh) trace-time constant
    qu = q + lp["u"][None, :, None, :]
    qv = q + lp["vb"][None, :, None, :]
    s = jnp.einsum("bhmd,bhnd->bhmn", qu, kcat)
    s = s + jnp.einsum("bhmd,nd->bhmn", qv, p)
    pr = ref.softmax_rows(s * scale)
    return jnp.einsum("bhmn,bhnd->bhmd", pr, vcat)


def _xl_layer(cfg: ModelConfig, lp: dict, x, kmem, vmem):
    m = x.shape[1]
    q, k, v = _qkv(cfg, lp, x)  # XL uses P, not RoPE
    kcat = jnp.concatenate([kmem, k], axis=2)
    vcat = jnp.concatenate([vmem, v], axis=2)
    a = _xl_attention(cfg, lp, q, kcat, vcat)
    a = _merge_heads(a) @ lp["wo"] + lp["bo"]
    x = _residual(cfg, lp, x, a, 0)
    x = _residual(cfg, lp, x, _ffn(cfg, lp, x), 1)
    return x, kcat[:, :, m:, :], vcat[:, :, m:, :]


def xl_step(cfg: ModelConfig, params: dict, tokens, kmem, vmem):
    """Continual DeepCoT-XL tick — deepcot_step contract minus `pos`
    (XL uses the relative matrix P, not RoPE)."""
    x = _embed(params, tokens)
    new_k, new_v = [], []
    for i, lp in enumerate(params["layers"]):
        x, k_i, v_i = _xl_layer(cfg, lp, x, kmem[i], vmem[i])
        new_k.append(k_i)
        new_v.append(v_i)
    return _readout(params, x[:, -1, :]), x, jnp.stack(new_k), jnp.stack(new_v)


def xl_full(cfg: ModelConfig, params: dict, window):
    """Non-continual TransformerXL-style window baseline."""
    x = _embed(params, window)
    for lp in params["layers"]:
        q, k, v = _qkv(cfg, lp, x)
        a = _xl_attention(cfg, lp, q, k, v)
        a = _merge_heads(a) @ lp["wo"] + lp["bo"]
        x = _residual(cfg, lp, x, a, 0)
        x = _residual(cfg, lp, x, _ffn(cfg, lp, x), 1)
    return _readout(params, x[:, -1, :]), x


FAMILIES = {
    "deepcot": deepcot_step,
    "encoder": encoder_full,
    "cotransformer": cotransformer_step,
    "nystrom": nystrom_full,
    "fnet": fnet_full,
    "xl": xl_step,
    "xl_full": xl_full,
}

STEP_FAMILIES = ("deepcot", "cotransformer", "xl")
WINDOW_FAMILIES = ("encoder", "nystrom", "fnet", "xl_full")
