"""AOT lowering: every registered variant -> artifacts/.

Outputs (all consumed by the Rust runtime, never Python at serve time):
  artifacts/hlo/<name>.hlo.txt     — HLO *text*. Not .serialize():
      xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
      ids); the text parser reassigns ids and round-trips cleanly.
  artifacts/weights/<spec-key>.bin — f32 LE params, param_spec order,
      deduplicated across variants sharing a spec (window size does not
      change parameter shapes).
  artifacts/golden/<name>.json     — input stream + expected outputs for
      the tiny variants (Rust integration tests).
  artifacts/manifest.json          — the contract: per-variant arg order,
      shapes, state wiring, weight file, golden file.

Usage: python -m compile.aot --out-dir ../artifacts [--only prefix]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, params as P, stream, variants
from .config import ModelConfig

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def io_spec(cfg: ModelConfig, family: str):
    """(inputs, outputs, state wiring) for a family.

    state wiring maps output index -> input index for the feedback loop
    the Rust coordinator runs (new memories become next tick's inputs).
    """
    b, m, n = cfg.batch, cfg.m_tokens, cfg.window
    d_in, d, c = cfg.d_in, cfg.d_model, cfg.n_classes
    lbhmd = [cfg.n_layers, b, cfg.n_heads, cfg.mem_len, cfg.d_head]
    bhnd = [b, cfg.n_heads, n - 1, cfg.d_head]
    if family in ("deepcot", "xl"):
        inputs = [("tokens", [b, m, d_in], F32)]
        if family == "deepcot":
            inputs.append(("pos", [], I32))  # xl uses P, not RoPE: no pos
        inputs += [("kmem", lbhmd, F32), ("vmem", lbhmd, F32)]
        outputs = [
            ("logits", [b, c], F32),
            ("out", [b, m, d], F32),
            ("kmem_next", lbhmd, F32),
            ("vmem_next", lbhmd, F32),
        ]
        k0 = len(inputs) - 2
        state = {"2": k0, "3": k0 + 1}
    elif family == "cotransformer":
        inputs = [
            ("tokens", [b, 1, d_in], F32),
            ("pos", [], I32),
            ("qmem", bhnd, F32),
            ("kmem", bhnd, F32),
            ("vmem", bhnd, F32),
        ]
        outputs = [
            ("logits", [b, c], F32),
            ("out", [b, 1, d], F32),
            ("qmem_next", bhnd, F32),
            ("kmem_next", bhnd, F32),
            ("vmem_next", bhnd, F32),
        ]
        state = {"2": 2, "3": 3, "4": 4}
    else:  # window families
        inputs = [("window", [b, n, d_in], F32)]
        if family not in ("fnet", "xl_full"):  # posless baselines
            inputs.append(("pos", [], I32))
        outputs = [("logits", [b, c], F32), ("out", [b, n, d], F32)]
        state = {}
    return inputs, outputs, state


def make_fn(cfg: ModelConfig, family: str):
    """Wrap a family forward as fn(*arrays, *flat_params)."""
    n_data = len(io_spec(cfg, family)[0])
    fwd = model.FAMILIES[family]

    def fn(*args):
        data, flat = args[:n_data], args[n_data:]
        p = P.unflatten(cfg, family, flat)
        return fwd(cfg, p, *data)

    return fn


def input_specs(cfg: ModelConfig, family: str):
    ins, _, _ = io_spec(cfg, family)
    specs = []
    for _, shape, dt in ins:
        dtype = jnp.float32 if dt == F32 else jnp.int32
        specs.append(jax.ShapeDtypeStruct(tuple(shape), dtype))
    for _, shape in P.param_spec(cfg, family):
        specs.append(jax.ShapeDtypeStruct(tuple(shape), jnp.float32))
    return specs


def spec_key(cfg: ModelConfig, family: str, seed: int) -> str:
    """Weights are shared by variants with identical param specs."""
    spec = P.param_spec(cfg, family)
    blob = json.dumps([(n, list(s)) for n, s in spec]) + f"|seed={seed}"
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def dump_golden(cfg: ModelConfig, family: str, pd: dict, path: pathlib.Path):
    """Run a short stream on the host and record expected outputs."""
    rng = np.random.default_rng(42)
    t = variants.GOLDEN_TICKS
    if family in ("deepcot", "xl"):
        toks = rng.standard_normal(
            (t, cfg.batch, cfg.m_tokens, cfg.d_in)
        ).astype(np.float32)
        run = stream.run_deepcot_stream if family == "deepcot" else stream.run_xl_stream
        logits, outs = run(cfg, pd, toks)
    elif family == "cotransformer":
        toks = rng.standard_normal((t, cfg.batch, 1, cfg.d_in)).astype(np.float32)
        logits, outs = stream.run_cotransformer_stream(cfg, pd, toks)
    else:
        flat = rng.standard_normal((t, cfg.batch, cfg.d_in)).astype(np.float32)
        fwd = model.FAMILIES[family]
        with_pos = family not in ("fnet", "xl_full")
        logits, outs = stream.run_window_stream(cfg, pd, fwd, flat, with_pos)
        toks = flat[:, :, None, :]
    payload = {
        "ticks": t,
        "stream": toks.reshape(t, -1).tolist(),
        "expected_logits": np.asarray(logits).reshape(t, -1).tolist(),
        "expected_out_last": np.asarray(outs)[:, :, -1, :].reshape(t, -1).tolist(),
    }
    path.write_text(json.dumps(payload))


def build(out_dir: pathlib.Path, only: str | None, seed: int = 0) -> None:
    hlo_dir = out_dir / "hlo"
    w_dir = out_dir / "weights"
    g_dir = out_dir / "golden"
    for d in (hlo_dir, w_dir, g_dir):
        d.mkdir(parents=True, exist_ok=True)

    manifest: dict = {"seed": seed, "variants": {}}
    written_weights: set[str] = set()
    todo = variants.all_variants()
    for name, family, cfg in todo:
        if only and not name.startswith(only):
            continue
        flat_np = P.init_params(cfg, family, seed)
        key = spec_key(cfg, family, seed)
        wfile = f"weights/{key}.bin"
        if key not in written_weights:
            with open(out_dir / wfile, "wb") as f:
                for arr in flat_np:
                    f.write(arr.astype("<f4").tobytes())
            written_weights.add(key)

        fn = make_fn(cfg, family)
        lowered = jax.jit(fn).lower(*input_specs(cfg, family))
        hlo = to_hlo_text(lowered)
        (hlo_dir / f"{name}.hlo.txt").write_text(hlo)

        ins, outs, state = io_spec(cfg, family)
        entry = {
            "family": family,
            "config": cfg.to_json(),
            "hlo": f"hlo/{name}.hlo.txt",
            "weights": wfile,
            "inputs": [
                {"name": n_, "shape": s, "dtype": dt} for n_, s, dt in ins
            ],
            "outputs": [
                {"name": n_, "shape": s, "dtype": dt} for n_, s, dt in outs
            ],
            "state": state,
            "params": [
                {"name": n_, "shape": list(s)}
                for n_, s in P.param_spec(cfg, family)
            ],
        }
        if name in variants.GOLDEN_VARIANTS:
            pd = P.unflatten(cfg, family, tuple(jnp.asarray(a) for a in flat_np))
            gfile = f"golden/{name}.json"
            dump_golden(cfg, family, pd, out_dir / gfile)
            entry["golden"] = gfile
        manifest["variants"][name] = entry
        print(f"lowered {name}  ({len(hlo)//1024} KiB hlo)")

    mpath = out_dir / "manifest.json"
    if only and mpath.exists():
        old = json.loads(mpath.read_text())
        old["variants"].update(manifest["variants"])
        manifest = old
    mpath.write_text(json.dumps(manifest, indent=1))
    print(f"wrote {mpath} ({len(manifest['variants'])} variants)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="variant name prefix filter")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build(pathlib.Path(args.out_dir), args.only, args.seed)


if __name__ == "__main__":
    main()
