"""Model configuration shared by L1/L2 and mirrored in the Rust manifest.

One dataclass describes every member of the encoder family; the `family`
string selects which forward function is AOT-lowered:

  deepcot        — stack of Single-Output continual layers (the paper)
  encoder        — regular sliding-window encoder (non-continual baseline)
  cotransformer  — Continual Transformer (retroactive L0 + single-output
                   rest; Hedegaard et al.) — 2-layer baseline
  nystrom        — Nystromformer window baseline
  fnet           — FNet (Fourier mixing) window baseline
  xl / xl_full   — DeepCoT-XL continual step / full-window Transformer-XL
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # geometry
    d_in: int  # input token feature size
    d_model: int
    n_heads: int
    n_layers: int
    window: int  # n — attention window / memory span
    m_tokens: int = 1  # tokens per stream tick (supp. §III m-output)
    ffn_mult: int = 4
    n_classes: int = 10
    batch: int = 1
    # variant switches (paper §III-B / supp. §II)
    activation: str = "softmax"  # softmax | soft
    norm: str = "layernorm"  # layernorm | rezero
    ffn_act: str = "gelu"  # gelu | linear
    pos: str = "rope"  # rope | none
    # baselines
    n_landmarks: int = 0  # nystrom only
    # implementation switch (perf pass may flip the default; see
    # EXPERIMENTS.md §Perf)
    use_pallas: bool = True

    def __post_init__(self):
        if self.d_model % self.n_heads:
            raise ValueError("d_model must divide by n_heads")
        if self.window <= self.m_tokens:
            raise ValueError("window must exceed m_tokens")
        if self.activation not in ("softmax", "soft"):
            raise ValueError(f"bad activation {self.activation}")
        if self.norm not in ("layernorm", "rezero"):
            raise ValueError(f"bad norm {self.norm}")
        if self.ffn_act not in ("gelu", "linear"):
            raise ValueError(f"bad ffn_act {self.ffn_act}")
        if self.pos not in ("rope", "none"):
            raise ValueError(f"bad pos {self.pos}")

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return self.ffn_mult * self.d_model

    @property
    def mem_len(self) -> int:
        """Rows kept in each layer's K/V memory: n - m (paper: (n-1) x d)."""
        return self.window - self.m_tokens

    def soft_paper_variant(self) -> "ModelConfig":
        """The mathematical-analysis configuration of §III-B: SOFT
        activation, linear FFN, ReZero residuals."""
        return dataclasses.replace(
            self, activation="soft", norm="rezero", ffn_act="linear"
        )

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ModelConfig":
        return ModelConfig(**d)
