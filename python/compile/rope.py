"""Rotary Position Embedding (RoPE, Su et al.) — the circular positional
embedding DeepCoT requires (supp. §III): rotations depend only on
relative offsets in the attention product, so streams of unbounded
length work without re-embedding the window.
"""

from __future__ import annotations

import jax.numpy as jnp

BASE = 10000.0


def rope_freqs(dh: int) -> jnp.ndarray:
    """Inverse frequencies for a head dim dh (must be even): (dh/2,)."""
    half = dh // 2
    return 1.0 / (BASE ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / dh))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Rotate x by its absolute positions.

    x: (..., T, dh) with dh even; positions: (T,) int32 -> same shape.
    Pairs are (x[2i], x[2i+1]) — interleaved convention.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh)  # (dh/2,)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    # re-interleave
    out = jnp.stack([out_even, out_odd], axis=-1)
    return out.reshape(x.shape)
