"""The registry of every AOT-compiled artifact — the per-experiment
geometry table (DESIGN.md §5).

Each variant = (family, ModelConfig, experiment tag). Window sizes for
T4 are the paper's exact Table-IV sizes; weight files are deduplicated
across variants that share a parameter spec (window size does not change
parameter shapes).

Pallas usage: T1/T2/T3 artifacts lower through the L1 Pallas kernels
(interpret=True). T4 and the Fig.-1 sweep lower the pure-jnp path: the
interpret-mode machinery adds lowering overhead at 12-layer x 16-window
scale with no numerical difference (kernels are pytest-verified against
the same oracles) — see EXPERIMENTS.md §Perf for the measured
comparison on the serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from .config import ModelConfig

Variant = tuple[str, str, ModelConfig]  # (name, family, cfg)


def _v(name: str, family: str, **kw) -> Variant:
    return (name, family, ModelConfig(**kw))


def _t4_cfg(window: int, family: str, soft: bool, batch: int = 1) -> ModelConfig:
    cfg = ModelConfig(
        d_in=64,
        d_model=256,
        n_heads=8,
        n_layers=12,
        window=window,
        n_classes=3,
        batch=batch,
        use_pallas=False,
    )
    return cfg.soft_paper_variant() if soft else cfg


# Table IV window sizes (paper, parenthesized numbers): task -> (x0.5, x1, x2)
T4_WINDOWS = {
    "cola": (6, 12, 24),
    "sst2": (12, 24, 48),
    "mrpc": (26, 52, 104),
    "stsb": (15, 30, 60),
    "qqp": (15, 30, 60),
    "mnli": (19, 38, 76),
    "qnli": (25, 50, 100),
}

# Fig. 1 / supp. Fig. 2-3 sweep windows (batch 16 in the paper; batch 4
# here — CPU-PJRT substrate, DESIGN.md §2).
FIG1_WINDOWS = (16, 32, 64, 128, 256, 512)
FIG1_BATCH = 4


def tiny_variants() -> Iterator[Variant]:
    """Small geometries with golden dumps — rust integration tests."""
    base = dict(
        d_in=8, d_model=16, n_heads=2, window=6, n_classes=3, batch=2
    )
    yield _v("tiny_deepcot", "deepcot", n_layers=2, **base)
    yield _v("tiny_deepcot_l1", "deepcot", n_layers=1, **base)
    yield _v("tiny_encoder", "encoder", n_layers=2, **base)
    yield _v("tiny_encoder_l1", "encoder", n_layers=1, **base)
    yield _v("tiny_cotransformer", "cotransformer", n_layers=2, **base)
    yield _v("tiny_xl", "xl", n_layers=2, **base)
    yield _v("tiny_xl_full", "xl_full", n_layers=2, **base)
    yield _v("tiny_fnet", "fnet", n_layers=2, **base)
    yield _v(
        "tiny_nystrom", "nystrom", n_layers=2, n_landmarks=3,
        d_in=8, d_model=16, n_heads=2, window=6, n_classes=3, batch=2,
    )
    soft = dict(base, activation="soft", norm="rezero", ffn_act="linear")
    yield _v("tiny_deepcot_soft", "deepcot", n_layers=2, **soft)
    yield _v("tiny_encoder_soft", "encoder", n_layers=2, **soft)
    # m-token variant (supp. §III)
    yield _v("tiny_deepcot_m3", "deepcot", n_layers=2, m_tokens=3, **base)


def t1_variants() -> Iterator[Variant]:
    """Table I — OAD, THUMOS14 geometry: 64-token windows, 2 layers,
    20 classes, continual one token at a time."""
    base = dict(
        d_in=64, d_model=128, n_heads=8, n_layers=2, window=64,
        n_classes=20, batch=1,
    )
    yield _v("t1_deepcot", "deepcot", **base)
    yield _v("t1_encoder", "encoder", **base)  # OAD Transformer stand-in
    yield _v("t1_cotransformer", "cotransformer", **base)
    yield _v("t1_nystrom", "nystrom", n_landmarks=16, **base)


def t2_variants() -> Iterator[Variant]:
    """Table II — GTZAN audio: 120 VGGish tokens, 2 layers, 10 genres."""
    base = dict(
        d_in=128, d_model=128, n_heads=4, n_layers=2, window=120,
        n_classes=10, batch=1,
    )
    yield _v("t2_deepcot", "deepcot", **base)
    yield _v("t2_encoder", "encoder", **base)
    yield _v("t2_cotransformer", "cotransformer", **base)
    yield _v("t2_nystrom", "nystrom", n_landmarks=4, **base)


def t3_variants() -> Iterator[Variant]:
    """Table III — MAT-SED: 10-layer encoder (m=12 tokens/tick) chained
    with a 3-layer TransformerXL context net (m=10 tokens/tick); the Rust
    coordinator pipelines the two executables (DESIGN.md §5)."""
    enc = dict(
        d_in=128, d_model=256, n_heads=8, n_layers=10, window=60,
        n_classes=10, batch=1,
    )
    # the context net consumes the encoder's m=12 attended tokens per
    # tick; its window covers 48 encoder outputs (4 ticks of context)
    ctx = dict(
        d_in=256, d_model=256, n_heads=8, n_layers=3, window=48,
        n_classes=10, batch=1,
    )
    yield _v("t3_deepcot_enc", "deepcot", m_tokens=12, **enc)
    yield _v("t3_encoder_enc", "encoder", **enc)
    yield _v("t3_deepcot_ctx", "xl", m_tokens=12, **ctx)
    yield _v("t3_encoder_ctx", "xl_full", **ctx)


def t4_variants() -> Iterator[Variant]:
    """Table IV — GLUE: 12-layer Roformer-like family at the paper's
    exact window sizes; softmax + SOFT(+ReZero+linear FFN) ablation."""
    windows = sorted({w for ws in T4_WINDOWS.values() for w in ws})
    for w in windows:
        yield (f"t4_deepcot_n{w}", "deepcot", _t4_cfg(w, "deepcot", False))
        yield (f"t4_encoder_n{w}", "encoder", _t4_cfg(w, "encoder", False))
        yield (f"t4_fnet_n{w}", "fnet", _t4_cfg(w, "fnet", False))
        yield (f"t4_deepcot_soft_n{w}", "deepcot", _t4_cfg(w, "deepcot", True))
        yield (f"t4_encoder_soft_n{w}", "encoder", _t4_cfg(w, "encoder", True))


def fig1_variants() -> Iterator[Variant]:
    """Fig. 1 + supp. Figs. 2-3 — latency/throughput vs window size."""
    for w in FIG1_WINDOWS:
        base = dict(
            d_in=64, d_model=256, n_heads=8, n_layers=6, window=w,
            n_classes=3, batch=FIG1_BATCH, use_pallas=False,
        )
        yield _v(f"fig1_deepcot_n{w}", "deepcot", **base)
        yield _v(f"fig1_encoder_n{w}", "encoder", **base)
        yield _v(f"fig1_fnet_n{w}", "fnet", **base)
        soft = dict(base, activation="soft", norm="rezero", ffn_act="linear")
        yield _v(f"fig1_deepcot_soft_n{w}", "deepcot", **soft)
        yield _v(f"fig1_encoder_soft_n{w}", "encoder", **soft)


def serve_variants() -> Iterator[Variant]:
    """Batched-slot executables for the serving engine: same model, batch
    dim = slot count buckets (DESIGN.md §3, slot-based continual
    batching).

    Perf note (EXPERIMENTS.md §Perf iteration 2): serving variants lower
    through the pure-jnp path — interpret-mode Pallas serializes its
    B*H-program grid into an XLA while-loop, which at B=16 costs ~30x
    wall clock on CPU PJRT. A Pallas twin of the b4 bucket is kept for
    the ablation; kernel numerics stay pytest-verified against the same
    oracles either way."""
    geo = dict(
        d_in=64, d_model=128, n_heads=8, n_layers=4, window=64, n_classes=10,
    )
    for b in (1, 4, 16):
        yield _v(f"serve_deepcot_b{b}", "deepcot", batch=b, use_pallas=False, **geo)
    yield _v("serve_deepcot_b4_pallas", "deepcot", batch=4, use_pallas=True, **geo)
    # jnp twin of the (pallas) t1 model for the same ablation at B=1
    yield _v(
        "t1_deepcot_jnp", "deepcot",
        d_in=64, d_model=128, n_heads=8, n_layers=2, window=64,
        n_classes=20, batch=1, use_pallas=False,
    )


def all_variants() -> list[Variant]:
    out: list[Variant] = []
    for gen in (
        tiny_variants,
        t1_variants,
        t2_variants,
        t3_variants,
        t4_variants,
        fig1_variants,
        serve_variants,
    ):
        out.extend(gen())
    names = [n for n, _, _ in out]
    assert len(names) == len(set(names)), "duplicate variant names"
    return out


GOLDEN_VARIANTS = [n for n, _, _ in tiny_variants()]
GOLDEN_TICKS = 12
