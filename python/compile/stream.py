"""Host-side streaming helpers (build/test-time only).

Drives the step functions over a whole stream the way the Rust
coordinator does at runtime: zero-initialized memories, one tick per m
tokens. Used by pytest (equivalence / receptive-field properties) and by
aot.py to dump golden sequences for the Rust integration tests.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import model
from .config import ModelConfig


def zero_memories(cfg: ModelConfig, n_mem: int = 2):
    """Fresh per-layer memories: n_mem tensors (L, B, H, M, dh)."""
    shape = (cfg.n_layers, cfg.batch, cfg.n_heads, cfg.mem_len, cfg.d_head)
    return tuple(jnp.zeros(shape, dtype=jnp.float32) for _ in range(n_mem))


def zero_cot_memories(cfg: ModelConfig):
    """Continual-Transformer layer-0 caches: q/k/v each (B, H, n-1, dh)."""
    shape = (cfg.batch, cfg.n_heads, cfg.window - 1, cfg.d_head)
    return tuple(jnp.zeros(shape, dtype=jnp.float32) for _ in range(3))


def run_deepcot_stream(cfg: ModelConfig, params: dict, stream: np.ndarray):
    """stream: (T, B, m, d_in). Returns (logits (T,B,C), outs (T,B,m,d))."""
    kmem, vmem = zero_memories(cfg)
    logits, outs = [], []
    for t in range(stream.shape[0]):
        pos = jnp.int32(t * cfg.m_tokens)
        lg, out, kmem, vmem = model.deepcot_step(
            cfg, params, jnp.asarray(stream[t]), pos, kmem, vmem
        )
        logits.append(np.asarray(lg))
        outs.append(np.asarray(out))
    return np.stack(logits), np.stack(outs)


def run_xl_stream(cfg: ModelConfig, params: dict, stream: np.ndarray):
    kmem, vmem = zero_memories(cfg)
    logits, outs = [], []
    for t in range(stream.shape[0]):
        lg, out, kmem, vmem = model.xl_step(
            cfg, params, jnp.asarray(stream[t]), kmem, vmem
        )
        logits.append(np.asarray(lg))
        outs.append(np.asarray(out))
    return np.stack(logits), np.stack(outs)


def run_cotransformer_stream(cfg: ModelConfig, params: dict, stream: np.ndarray):
    """stream: (T, B, 1, d_in)."""
    qmem, kmem, vmem = zero_cot_memories(cfg)
    logits, outs = [], []
    for t in range(stream.shape[0]):
        lg, out, qmem, kmem, vmem = model.cotransformer_step(
            cfg, params, jnp.asarray(stream[t]), jnp.int32(t), qmem, kmem, vmem
        )
        logits.append(np.asarray(lg))
        outs.append(np.asarray(out))
    return np.stack(logits), np.stack(outs)


def run_window_stream(cfg: ModelConfig, params: dict, fn, tokens: np.ndarray,
                      with_pos: bool = True):
    """Slide a window over tokens (T, B, d_in), re-running `fn` per tick —
    the non-continual serving pattern. Ticks with fewer than n tokens seen
    are left-padded with zeros (cold-start convention shared with the
    zero-initialized continual memories)."""
    t_total, b, d_in = tokens.shape
    n = cfg.window
    logits, outs = [], []
    for t in range(t_total):
        lo = t - n + 1
        if lo < 0:
            pad = np.zeros((-lo, b, d_in), dtype=tokens.dtype)
            win = np.concatenate([pad, tokens[: t + 1]], axis=0)
        else:
            win = tokens[lo : t + 1]
        win = jnp.asarray(win.transpose(1, 0, 2))  # (B, n, d_in)
        if with_pos:
            lg, out = fn(cfg, params, win, jnp.int32(lo))
        else:
            lg, out = fn(cfg, params, win)
        logits.append(np.asarray(lg))
        outs.append(np.asarray(out))
    return np.stack(logits), np.stack(outs)
